//! Fig. 8: resource utilisation and performance vs PE count, plus the
//! eq. (2) analytic-model cross-check the paper reports ("matches the
//! practical results").

use crate::accel::dse::{sweep, sweep_grid, DsePoint};
use crate::accel::latency::predict_batch_cycles;
use crate::accel::resource::AccelConfig;
use crate::accel::Scheme;
use crate::ivim::synth::synth_dataset;
use crate::model::{Manifest, Weights};

/// Paper's swept PE counts.
pub const PAPER_PE_COUNTS: [usize; 5] = [4, 8, 16, 32, 64];

/// The Fig. 8 sweep: returns DSE points and per-point analytic-model
/// agreement (predicted cycles == simulated cycles).
pub fn fig8(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
) -> anyhow::Result<(Vec<DsePoint>, Vec<bool>)> {
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 31);
    let points = sweep(man, weights, pe_counts, Scheme::BatchLevel, &ds.signals)?;
    let mut model_ok = Vec::with_capacity(points.len());
    for p in &points {
        let cfg = AccelConfig {
            n_pe: p.n_pe,
            batch: man.batch_infer,
            ..Default::default()
        };
        let predicted = predict_batch_cycles(man, &cfg, Scheme::BatchLevel);
        let simulated = (p.batch_ms / 1e3 * cfg.clock_hz).round() as u64;
        model_ok.push(predicted == simulated);
    }
    Ok((points, model_ok))
}

/// Parse a `--keep-rates` CLI value: comma-separated keep probabilities,
/// each in (0, 1].  Returns a friendly error naming the offending token.
pub fn parse_keep_rates(spec: &str) -> anyhow::Result<Vec<f64>> {
    let mut rates = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        let r: f64 = tok
            .parse()
            .map_err(|_| anyhow::anyhow!("--keep-rates: '{tok}' is not a number"))?;
        anyhow::ensure!(
            r > 0.0 && r <= 1.0,
            "--keep-rates: {r} outside (0, 1] (a keep rate is the fraction of neurons retained)"
        );
        rates.push(r);
    }
    anyhow::ensure!(!rates.is_empty(), "--keep-rates: empty list");
    Ok(rates)
}

/// The Fig. 8 grid sweep (`--keep-rates`): PE count × mask keep rate on
/// one reused simulator, mask resampling seeded by `mask_seed`.  The
/// eq. (2) cross-check is skipped — the analytic model assumes the
/// manifest's masks, not resampled ones — so the returned rows pair with
/// an **empty** `model_ok` in [`render`].
pub fn fig8_grid(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
    keep_rates: &[f64],
    mask_seed: u64,
) -> anyhow::Result<Vec<DsePoint>> {
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 31);
    sweep_grid(
        man,
        weights,
        pe_counts,
        keep_rates,
        Scheme::BatchLevel,
        &ds.signals,
        mask_seed,
    )
}

/// Render the Fig. 8 table + plot.  Rows from `dse::sweep_grid` carry a
/// mask keep rate; the column is shown whenever any row has one.  An
/// empty `model_ok` drops the eq. (2) column entirely (grid sweeps don't
/// run the analytic cross-check).
pub fn render(points: &[DsePoint], model_ok: &[bool]) -> String {
    use crate::metrics::report::{ascii_plot, Table};
    let with_masks = points.iter().any(|p| p.keep_prob.is_some());
    let with_model = !model_ok.is_empty();
    let mut headers = vec!["PEs"];
    if with_masks {
        headers.push("keep");
    }
    headers.extend([
        "DSP%", "BRAM%", "LUT%", "IO%", "power (W)", "ms/batch", "kvox/s", "fits",
    ]);
    if with_model {
        headers.push("eq2==sim");
    }
    let mut t = Table::new(&headers);
    for (i, p) in points.iter().enumerate() {
        let mut cells = vec![p.n_pe.to_string()];
        if with_masks {
            cells.push(
                p.keep_prob
                    .map(|k| format!("{k:.2}"))
                    .unwrap_or_else(|| "manifest".into()),
            );
        }
        cells.extend([
            format!("{:.1}", p.usage.dsp_pct()),
            format!("{:.1}", p.usage.bram_pct()),
            format!("{:.1}", p.usage.lut_pct()),
            format!("{:.1}", p.usage.io_pct()),
            format!("{:.2}", p.power.watts),
            format!("{:.4}", p.batch_ms),
            format!("{:.1}", p.voxels_per_s / 1e3),
            p.fits.to_string(),
        ]);
        if with_model {
            cells.push(model_ok.get(i).copied().unwrap_or(false).to_string());
        }
        t.row(&cells);
    }
    if with_masks {
        // Grid rows repeat every PE count once per keep rate: plot one
        // speed series per rate (all rates share the PE axis) instead of
        // conflating them into one zig-zag curve.
        let mut rates: Vec<f64> = Vec::new();
        for p in points {
            if let Some(k) = p.keep_prob {
                if !rates.iter().any(|r| (r - k).abs() < 1e-12) {
                    rates.push(k);
                }
            }
        }
        let xs: Vec<f64> = points
            .iter()
            .filter(|p| p.keep_prob == Some(rates[0]))
            .map(|p| p.n_pe as f64)
            .collect();
        let labels: Vec<String> = rates.iter().map(|k| format!("kvox/s keep={k:.2}")).collect();
        let series: Vec<(&str, Vec<f64>)> = rates
            .iter()
            .zip(&labels)
            .map(|(&k, label)| {
                (
                    label.as_str(),
                    points
                        .iter()
                        .filter(|p| p.keep_prob == Some(k))
                        .map(|p| p.voxels_per_s / 1e3)
                        .collect(),
                )
            })
            .collect();
        return format!(
            "{}\n{}",
            t.to_text(),
            ascii_plot("Fig. 8 — speed vs PE count per mask keep rate", &xs, &series, 10)
        );
    }
    let xs: Vec<f64> = points.iter().map(|p| p.n_pe as f64).collect();
    let speed: Vec<f64> = points.iter().map(|p| p.voxels_per_s / 1e3).collect();
    let dsp: Vec<f64> = points.iter().map(|p| p.usage.dsp_pct()).collect();
    let bram: Vec<f64> = points.iter().map(|p| p.usage.bram_pct()).collect();
    format!(
        "{}\n{}",
        t.to_text(),
        ascii_plot(
            "Fig. 8 — utilisation & speed vs PE count",
            &xs,
            &[("kvox/s", speed), ("DSP%", dsp), ("BRAM%", bram)],
            10
        )
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    /// Grid rows (PE × keep rate, one reused simulator) render with the
    /// keep column; manifest-mask sweeps keep the paper's plain layout.
    #[test]
    fn render_shows_keep_column_for_grid_rows() {
        use crate::accel::dse;
        use crate::ivim::synth::synth_dataset;
        let (man, w) = crate::testing::fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 13);
        let rows = dse::sweep_grid(
            &man,
            &w,
            &[8, 16],
            &[0.9, 0.3],
            Scheme::BatchLevel,
            &ds.signals,
            3,
        )
        .unwrap();
        let ok = vec![true; rows.len()];
        let s = render(&rows, &ok);
        assert!(s.contains("keep") && s.contains("0.90") && s.contains("0.30"), "{s}");
        // one plotted speed series per keep rate, never a conflated curve
        assert!(s.contains("keep=0.90") && s.contains("keep=0.30"), "{s}");
        let plain = dse::sweep(&man, &w, &[8], Scheme::BatchLevel, &ds.signals).unwrap();
        assert!(!render(&plain, &[true]).contains("keep"));
    }

    /// CLI-parse smoke test for `repro fig8 --keep-rates`: the option
    /// string round-trips through the same parser `main.rs` uses.
    #[test]
    fn parse_keep_rates_accepts_valid_and_rejects_garbage() {
        assert_eq!(parse_keep_rates("0.5").unwrap(), vec![0.5]);
        assert_eq!(
            parse_keep_rates(" 0.9, 0.5 ,0.25").unwrap(),
            vec![0.9, 0.5, 0.25]
        );
        assert_eq!(parse_keep_rates("1.0").unwrap(), vec![1.0]);
        for bad in ["", "abc", "0.5,x", "0.0", "-0.5", "1.5", "0.5,,0.25"] {
            assert!(parse_keep_rates(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    /// `fig8_grid` + `render` end-to-end on the fixture: one row per
    /// (PE, rate) pair, keep column shown, eq2 column dropped (grid
    /// sweeps skip the analytic cross-check).
    #[test]
    fn fig8_grid_renders_without_model_column() {
        let (man, w) = crate::testing::fixture::tiny_fixture();
        let rows = fig8_grid(&man, &w, &[8, 16], &[0.9, 0.5], 17).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|p| p.keep_prob.is_some()));
        let s = render(&rows, &[]);
        assert!(s.contains("keep") && s.contains("0.90") && s.contains("0.50"), "{s}");
        assert!(!s.contains("eq2==sim"), "grid render must drop the eq2 column:\n{s}");
        assert!(s.contains("Fig. 8"));
    }

    #[test]
    fn fig8_model_check_and_shapes() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let (points, ok) = fig8(&man, &w, &[4, 8, 16]).unwrap();
        assert_eq!(points.len(), 3);
        // paper: "the processing speed can be estimated based on
        // equation (2), which matches the practical results"
        assert!(ok.iter().all(|&b| b), "analytic model diverged: {ok:?}");
        // speed monotone non-decreasing in PEs
        for w2 in points.windows(2) {
            assert!(w2[1].voxels_per_s >= w2[0].voxels_per_s);
        }
        assert!(render(&points, &ok).contains("Fig. 8"));
    }
}
