//! Fig. 8: resource utilisation and performance vs PE count, plus the
//! eq. (2) analytic-model cross-check the paper reports ("matches the
//! practical results").

use crate::accel::dse::{sweep, DsePoint};
use crate::accel::latency::predict_batch_cycles;
use crate::accel::resource::AccelConfig;
use crate::accel::Scheme;
use crate::ivim::synth::synth_dataset;
use crate::model::{Manifest, Weights};

/// Paper's swept PE counts.
pub const PAPER_PE_COUNTS: [usize; 5] = [4, 8, 16, 32, 64];

/// The Fig. 8 sweep: returns DSE points and per-point analytic-model
/// agreement (predicted cycles == simulated cycles).
pub fn fig8(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
) -> anyhow::Result<(Vec<DsePoint>, Vec<bool>)> {
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 31);
    let points = sweep(man, weights, pe_counts, Scheme::BatchLevel, &ds.signals)?;
    let mut model_ok = Vec::with_capacity(points.len());
    for p in &points {
        let cfg = AccelConfig {
            n_pe: p.n_pe,
            batch: man.batch_infer,
            ..Default::default()
        };
        let predicted = predict_batch_cycles(man, &cfg, Scheme::BatchLevel);
        let simulated = (p.batch_ms / 1e3 * cfg.clock_hz).round() as u64;
        model_ok.push(predicted == simulated);
    }
    Ok((points, model_ok))
}

/// Render the Fig. 8 table + plot.
pub fn render(points: &[DsePoint], model_ok: &[bool]) -> String {
    use crate::metrics::report::{ascii_plot, Table};
    let mut t = Table::new(&[
        "PEs", "DSP%", "BRAM%", "LUT%", "IO%", "power (W)", "ms/batch", "kvox/s", "fits",
        "eq2==sim",
    ]);
    for (p, ok) in points.iter().zip(model_ok) {
        t.row(&[
            p.n_pe.to_string(),
            format!("{:.1}", p.usage.dsp_pct()),
            format!("{:.1}", p.usage.bram_pct()),
            format!("{:.1}", p.usage.lut_pct()),
            format!("{:.1}", p.usage.io_pct()),
            format!("{:.2}", p.power.watts),
            format!("{:.4}", p.batch_ms),
            format!("{:.1}", p.voxels_per_s / 1e3),
            p.fits.to_string(),
            ok.to_string(),
        ]);
    }
    let xs: Vec<f64> = points.iter().map(|p| p.n_pe as f64).collect();
    let speed: Vec<f64> = points.iter().map(|p| p.voxels_per_s / 1e3).collect();
    let dsp: Vec<f64> = points.iter().map(|p| p.usage.dsp_pct()).collect();
    let bram: Vec<f64> = points.iter().map(|p| p.usage.bram_pct()).collect();
    format!(
        "{}\n{}",
        t.to_text(),
        ascii_plot(
            "Fig. 8 — utilisation & speed vs PE count",
            &xs,
            &[("kvox/s", speed), ("DSP%", dsp), ("BRAM%", bram)],
            10
        )
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn fig8_model_check_and_shapes() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let (points, ok) = fig8(&man, &w, &[4, 8, 16]).unwrap();
        assert_eq!(points.len(), 3);
        // paper: "the processing speed can be estimated based on
        // equation (2), which matches the practical results"
        assert!(ok.iter().all(|&b| b), "analytic model diverged: {ok:?}");
        // speed monotone non-decreasing in PEs
        for w2 in points.windows(2) {
            assert!(w2[1].voxels_per_s >= w2[0].voxels_per_s);
        }
        assert!(render(&points, &ok).contains("Fig. 8"));
    }
}
