//! Minimal clap-substitute argument parser (DESIGN.md §7): subcommands,
//! `--key value` options, `--flag` booleans, automatic help text.

use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A subcommand spec.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Parsed arguments for one command.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'"))
            })
            .transpose()
    }
    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'"))
            })
            .transpose()
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// The CLI definition: a set of commands.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Parse argv (without the program name).  Returns parsed args or a
    /// help/usage error message to print.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        if argv.is_empty()
            || argv[0] == "--help"
            || argv[0] == "-h"
            || argv[0] == "help"
        {
            return Err(self.usage());
        }
        let cmd_name = &argv[0];
        let Some(spec) = self.commands.iter().find(|c| c.name == cmd_name) else {
            return Err(format!(
                "unknown command '{cmd_name}'\n\n{}",
                self.usage()
            ));
        };
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        // defaults
        for o in &spec.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_usage(spec));
            }
            let Some(name) = a.strip_prefix("--") else {
                return Err(format!(
                    "unexpected positional argument '{a}'\n\n{}",
                    self.command_usage(spec)
                ));
            };
            let Some(opt) = spec.opts.iter().find(|o| o.name == name) else {
                return Err(format!(
                    "unknown option '--{name}'\n\n{}",
                    self.command_usage(spec)
                ));
            };
            if opt.is_flag {
                flags.insert(name.to_string(), true);
                i += 1;
            } else {
                let Some(v) = argv.get(i + 1) else {
                    return Err(format!("--{name} requires a value"));
                };
                values.insert(name.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Args {
            command: cmd_name.clone(),
            values,
            flags,
        })
    }

    /// Top-level usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.program, self.about, self.program);
        let w = self
            .commands
            .iter()
            .map(|c| c.name.len())
            .max()
            .unwrap_or(0);
        for c in &self.commands {
            s.push_str(&format!("  {:<width$}  {}\n", c.name, c.help, width = w));
        }
        s.push_str(&format!(
            "\nRun '{} <command> --help' for command options.\n",
            self.program
        ));
        s
    }

    fn command_usage(&self, spec: &CommandSpec) -> String {
        let mut s = format!(
            "{} {} — {}\n\nOPTIONS:\n",
            self.program, spec.name, spec.help
        );
        for o in &spec.opts {
            let head = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <value>", o.name)
            };
            let dflt = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {head:<24}  {}{dflt}\n", o.help));
        }
        s
    }
}

/// Shorthand option constructors.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        default,
        is_flag: false,
    }
}
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            program: "repro",
            about: "test cli",
            commands: vec![CommandSpec {
                name: "train",
                help: "train the model",
                opts: vec![
                    opt("steps", "training steps", Some("500")),
                    opt("snr", "train snr", Some("20")),
                    flag("verbose", "chatty"),
                ],
            }],
        }
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let c = cli();
        let a = c
            .parse(&["train".into(), "--steps".into(), "10".into()])
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get_usize("steps").unwrap(), Some(10));
        assert_eq!(a.get_f64("snr").unwrap(), Some(20.0));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_flags() {
        let c = cli();
        let a = c.parse(&["train".into(), "--verbose".into()]).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn help_and_errors() {
        let c = cli();
        assert!(c.parse(&[]).is_err());
        assert!(c.parse(&["help".into()]).unwrap_err().contains("COMMANDS"));
        assert!(c
            .parse(&["nope".into()])
            .unwrap_err()
            .contains("unknown command"));
        assert!(c
            .parse(&["train".into(), "--bogus".into(), "1".into()])
            .unwrap_err()
            .contains("unknown option"));
        assert!(c
            .parse(&["train".into(), "--steps".into()])
            .unwrap_err()
            .contains("requires a value"));
        assert!(c
            .parse(&["train".into(), "--help".into()])
            .unwrap_err()
            .contains("OPTIONS"));
    }

    #[test]
    fn bad_number_reports_nicely() {
        let c = cli();
        let a = c
            .parse(&["train".into(), "--steps".into(), "abc".into()])
            .unwrap();
        assert!(a.get_usize("steps").is_err());
    }
}
