//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path.  Python never runs here.
//!
//! The real implementation (behind the `pjrt` cargo feature) follows the
//! load-HLO pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`.  Interchange is HLO *text*; see
//! `python/compile/aot.py::to_hlo_text`.
//!
//! The `xla` crate is not available in the offline registry, so the
//! default build ships a **stub** with the identical API surface:
//! `Runtime::cpu()` returns an error and the executable wrappers cannot
//! be constructed.  Everything that only needs the native or
//! accelerator-sim engines keeps working; PJRT-dependent paths degrade
//! gracefully at runtime (see rust/DESIGN.md §L2).

#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(feature = "pjrt")]
pub use executable::{InferExecutable, Runtime, TrainExecutable};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{InferExecutable, Runtime, TrainExecutable};

use crate::model::Weights;

/// Mutable optimisation state for the trainer (plain data — shared by the
/// real executables and the stub).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub weights: Weights,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl TrainState {
    pub fn fresh(weights: Weights) -> Self {
        let z = vec![0.0f32; weights.params.len()];
        TrainState {
            m: z.clone(),
            v: z,
            step: 0,
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_state_is_zeroed() {
        let w = Weights {
            params: vec![1.0, 2.0, 3.0],
            bn: vec![0.5],
        };
        let s = TrainState::fresh(w);
        assert_eq!(s.step, 0);
        assert_eq!(s.m, vec![0.0; 3]);
        assert_eq!(s.v, vec![0.0; 3]);
        assert_eq!(s.weights.params, vec![1.0, 2.0, 3.0]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let e = Runtime::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
