//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path.  Python never runs here.
//!
//! The real implementation (behind the `pjrt` cargo feature) follows the
//! load-HLO pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`.  Interchange is HLO *text*; see
//! `python/compile/aot.py::to_hlo_text`.
//!
//! The `xla` crate is not available in the offline registry, so the
//! default build ships a **stub** with the identical API surface:
//! `Runtime::cpu()` returns an error and the executable wrappers cannot
//! be constructed.  Everything that only needs the native or
//! accelerator-sim engines keeps working; PJRT-dependent paths degrade
//! gracefully at runtime (see rust/DESIGN.md §L2).

#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(feature = "pjrt")]
pub use executable::{InferExecutable, Runtime, TrainExecutable};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{InferExecutable, Runtime, TrainExecutable};

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::model::Weights;

static SHARED_CPU_ATTEMPTS: AtomicUsize = AtomicUsize::new(0);

/// The shared PJRT CPU client handle [`shared_cpu`] hands out:
/// `&'static` on the stub build (process-wide cache), `Rc` under the
/// real `pjrt` feature (per-thread cache — PJRT handles are `Rc`-based
/// and must never cross threads).  Both deref to [`Runtime`].
#[cfg(not(feature = "pjrt"))]
pub type SharedRuntime = &'static Runtime;
#[cfg(feature = "pjrt")]
pub type SharedRuntime = std::rc::Rc<Runtime>;

/// The shared PJRT CPU client.
///
/// PJRT client construction is the expensive part of the `pjrt` engine
/// (plugin load + device enumeration); building one per
/// `registry::build("pjrt")` call meant one client per SNR level in
/// `snr_sweep --engine pjrt` (ROADMAP).  Repeated builds now share a
/// cached client instead of re-constructing.
///
/// Stub build: the outcome is decided at compile time (`Runtime::cpu()`
/// always fails without the `pjrt` feature), so the first result —
/// including that permanent failure — is cached process-wide in a
/// `OnceLock` and every later build shares the single construction
/// attempt (what the registry test pins down).  Real `pjrt` build: the
/// client is cached **per thread** (engines and their runtimes are
/// `Rc`-based, not `Send`, and each coordinator shard builds in its own
/// thread), and only *successes* are cached — a transient init failure
/// is retried on the next build rather than poisoning the process.
#[cfg(not(feature = "pjrt"))]
pub fn shared_cpu() -> anyhow::Result<SharedRuntime> {
    static SHARED: std::sync::OnceLock<Result<Runtime, String>> = std::sync::OnceLock::new();
    let cached = SHARED.get_or_init(|| {
        SHARED_CPU_ATTEMPTS.fetch_add(1, Ordering::SeqCst);
        Runtime::cpu().map_err(|e| format!("{e:#}"))
    });
    match cached {
        Ok(rt) => Ok(rt),
        Err(msg) => Err(anyhow::anyhow!("{msg}")),
    }
}

/// See the stub-side docs above: per-thread success-only cache.
#[cfg(feature = "pjrt")]
pub fn shared_cpu() -> anyhow::Result<SharedRuntime> {
    use std::cell::RefCell;
    use std::rc::Rc;
    thread_local! {
        static CLIENT: RefCell<Option<Rc<Runtime>>> = const { RefCell::new(None) };
    }
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(rt) = slot.as_ref() {
            return Ok(Rc::clone(rt));
        }
        SHARED_CPU_ATTEMPTS.fetch_add(1, Ordering::SeqCst);
        let rt = Rc::new(Runtime::cpu()?);
        *slot = Some(Rc::clone(&rt));
        Ok(rt)
    })
}

/// How many times [`shared_cpu`] actually constructed (or tried to
/// construct) a client — observability hook.  Stub build: exactly 1
/// after any number of calls.
pub fn shared_cpu_attempts() -> usize {
    SHARED_CPU_ATTEMPTS.load(Ordering::SeqCst)
}

/// Mutable optimisation state for the trainer (plain data — shared by the
/// real executables and the stub).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub weights: Weights,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl TrainState {
    pub fn fresh(weights: Weights) -> Self {
        let z = vec![0.0f32; weights.params.len()];
        TrainState {
            m: z.clone(),
            v: z,
            step: 0,
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_state_is_zeroed() {
        let w = Weights {
            params: vec![1.0, 2.0, 3.0],
            bn: vec![0.5],
        };
        let s = TrainState::fresh(w);
        assert_eq!(s.step, 0);
        assert_eq!(s.m, vec![0.0; 3]);
        assert_eq!(s.v, vec![0.0; 3]);
        assert_eq!(s.weights.params, vec![1.0, 2.0, 3.0]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let e = Runtime::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn shared_cpu_constructs_exactly_once_per_process() {
        let a = shared_cpu().unwrap_err().to_string();
        let b = shared_cpu().unwrap_err().to_string();
        assert_eq!(a, b, "cached outcome is stable");
        assert!(a.contains("pjrt"), "{a}");
        assert_eq!(shared_cpu_attempts(), 1, "one construction, ever");
    }
}
