//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path.  Python never runs here.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text*; see `python/compile/aot.py::to_hlo_text`.

pub mod executable;

pub use executable::{InferExecutable, TrainExecutable, TrainState};

use std::sync::Arc;

/// Shared PJRT CPU client.  Creating a client is expensive; one per
/// process is plenty (thread-safe executions).
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    #[allow(dead_code)]
    pub(crate) fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO-text file and compile it to a loaded executable.
    pub fn compile_hlo_text(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        anyhow::ensure!(path.exists(), "HLO file missing: {}", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
    }
}

/// Convert a f32 slice into a literal of the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {:?} wants {} elements, got {}",
        dims,
        numel,
        data.len()
    );
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a Vec<f32> out of a literal.
pub fn literal_to_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))
}

/// Execute a loaded executable on literals, untupling the single tuple
/// result into its element literals.
pub fn execute_untuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> anyhow::Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
    anyhow::ensure!(!result.is_empty() && !result[0].is_empty(), "empty result");
    let mut outs = Vec::new();
    for buf in &result[0] {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: a single tuple literal.
        match lit.shape() {
            Ok(xla::Shape::Tuple(_)) => {
                let mut l = lit;
                outs.extend(
                    l.decompose_tuple()
                        .map_err(|e| anyhow::anyhow!("decompose: {e:?}"))?,
                );
            }
            _ => outs.push(lit),
        }
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{artifacts_root, Manifest};

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(literal_to_vec(&lit).unwrap(), data);
        assert!(literal_f32(&data, &[7]).is_err());
    }

    #[test]
    fn compiles_tiny_infer_hlo() {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.compile_hlo_text(&man.file("infer").unwrap());
        assert!(exe.is_ok(), "{:?}", exe.err());
    }
}
