//! The real PJRT runtime (requires the `pjrt` feature and a vendored
//! `xla` crate): CPU client, HLO-text compilation, literal plumbing and
//! typed wrappers over the two AOT executables:
//!
//! * [`InferExecutable`] — `(params, bn, signals[B,Nb]) -> (d, dstar, f,
//!   s0, recon)`, each output `[N,B]` (recon `[N,B,Nb]`).
//! * [`TrainExecutable`] — one Adam step `(params, bn, m, v, step,
//!   signals[B,Nb]) -> (params', bn', m', v', loss)`.
//!
//! Both validate the golden vectors shipped with the artifacts on demand
//! (`verify_golden`), which is the cross-language correctness gate.

use std::sync::Arc;

use super::TrainState;
use crate::infer::{Engine, InferOutput};
use crate::ivim::Param;
use crate::model::{Manifest, Weights};

/// Shared PJRT CPU client.  Creating a client is expensive; one per
/// process is plenty (thread-safe executions).
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text file and compile it to a loaded executable.
    pub fn compile_hlo_text(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        anyhow::ensure!(path.exists(), "HLO file missing: {}", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
    }
}

/// Convert a f32 slice into a literal of the given dims.
fn literal_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {:?} wants {} elements, got {}",
        dims,
        numel,
        data.len()
    );
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

/// Scalar f32 literal.
fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a Vec<f32> out of a literal.
fn literal_to_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))
}

/// Execute a loaded executable on literals, untupling the single tuple
/// result into its element literals.
fn execute_untuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> anyhow::Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
    anyhow::ensure!(!result.is_empty() && !result[0].is_empty(), "empty result");
    let mut outs = Vec::new();
    for buf in &result[0] {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: a single tuple literal.
        match lit.shape() {
            Ok(xla::Shape::Tuple(_)) => {
                let mut l = lit;
                outs.extend(
                    l.decompose_tuple()
                        .map_err(|e| anyhow::anyhow!("decompose: {e:?}"))?,
                );
            }
            _ => outs.push(lit),
        }
    }
    Ok(outs)
}

/// Compiled inference executable bound to its manifest and weights.
pub struct InferExecutable {
    exe: xla::PjRtLoadedExecutable,
    man: Manifest,
    params: Vec<f32>,
    bn: Vec<f32>,
}

impl InferExecutable {
    /// Compile the manifest's `infer` HLO on the given runtime.
    pub fn load(rt: &Runtime, man: &Manifest, weights: &Weights) -> anyhow::Result<Self> {
        let exe = rt.compile_hlo_text(&man.file("infer")?)?;
        Ok(InferExecutable {
            exe,
            man: man.clone(),
            params: weights.params.clone(),
            bn: weights.bn.clone(),
        })
    }

    /// Swap in new weights (e.g. after training).
    pub fn set_weights(&mut self, weights: &Weights) {
        self.params = weights.params.clone();
        self.bn = weights.bn.clone();
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// Execute on one batch, returning per-sample outputs plus the raw
    /// reconstruction plane `[N*B*Nb]`.
    pub fn infer_with_recon(
        &self,
        signals: &[f32],
    ) -> anyhow::Result<(InferOutput, Vec<f32>)> {
        let b = self.man.batch_infer;
        let nb = self.man.nb;
        anyhow::ensure!(
            signals.len() == b * nb,
            "expected {b}x{nb} signals, got {}",
            signals.len()
        );
        let args = [
            literal_f32(&self.params, &[self.man.param_count as i64])?,
            literal_f32(&self.bn, &[self.man.bn_count as i64])?,
            literal_f32(signals, &[b as i64, nb as i64])?,
        ];
        let outs = execute_untuple(&self.exe, &args)?;
        anyhow::ensure!(outs.len() == 5, "want 5 outputs, got {}", outs.len());
        let n = self.man.n_samples;
        let mut result = InferOutput::new(n, b);
        for (pi, p) in Param::ALL.iter().enumerate() {
            let plane = literal_to_vec(&outs[pi])?;
            anyhow::ensure!(plane.len() == n * b, "plane size mismatch");
            result.samples[p.index()] = plane;
        }
        let recon = literal_to_vec(&outs[4])?;
        anyhow::ensure!(recon.len() == n * b * nb, "recon size mismatch");
        Ok((result, recon))
    }

    /// Check the executable reproduces the python-side golden outputs.
    pub fn verify_golden(&self) -> anyhow::Result<()> {
        // Goldens are captured against the artifact's *initial* weights.
        let init = Weights::load_init(&self.man)?;
        let gin = crate::util::read_f32_file(&self.man.file("golden_in")?)?;
        let gout = crate::util::read_f32_file(&self.man.file("golden_out")?)?;
        let args = [
            literal_f32(&init.params, &[self.man.param_count as i64])?,
            literal_f32(&init.bn, &[self.man.bn_count as i64])?,
            literal_f32(&gin, &[self.man.batch_infer as i64, self.man.nb as i64])?,
        ];
        let outs = execute_untuple(&self.exe, &args)?;
        let mut got = Vec::new();
        for o in &outs {
            got.extend(literal_to_vec(o)?);
        }
        anyhow::ensure!(got.len() == gout.len(), "golden length mismatch");
        let max_diff = got
            .iter()
            .zip(&gout)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        anyhow::ensure!(
            max_diff < 1e-3,
            "golden mismatch: max |diff| = {max_diff}"
        );
        Ok(())
    }
}

impl Engine for InferExecutable {
    fn name(&self) -> &str {
        "pjrt-xla"
    }
    fn batch_size(&self) -> usize {
        self.man.batch_infer
    }
    fn n_samples(&self) -> usize {
        self.man.n_samples
    }
    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        // The PJRT FFI boundary materialises literals on its side of the
        // fence regardless; reuse the caller's planes for the copy-out
        // (clear+extend, not reset: every element is copied anyway, so
        // the zero-fill would be a redundant second write pass).
        let (res, _) = self.infer_with_recon(signals)?;
        out.n_samples = res.n_samples;
        out.batch = res.batch;
        for (dst, src) in out.samples.iter_mut().zip(res.samples.iter()) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        Ok(())
    }
}

/// Compiled train-step executable.
pub struct TrainExecutable {
    exe: xla::PjRtLoadedExecutable,
    man: Manifest,
}

impl TrainExecutable {
    pub fn load(rt: &Runtime, man: &Manifest) -> anyhow::Result<Self> {
        let exe = rt.compile_hlo_text(&man.file("train")?)?;
        Ok(TrainExecutable {
            exe,
            man: man.clone(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// One Adam step on a batch of `batch_train` voxels; updates `state`
    /// in place and returns the loss.
    pub fn step(&self, state: &mut TrainState, signals: &[f32]) -> anyhow::Result<f32> {
        let b = self.man.batch_train;
        let nb = self.man.nb;
        anyhow::ensure!(
            signals.len() == b * nb,
            "expected {b}x{nb} signals, got {}",
            signals.len()
        );
        let pc = self.man.param_count as i64;
        let args = [
            literal_f32(&state.weights.params, &[pc])?,
            literal_f32(&state.weights.bn, &[self.man.bn_count as i64])?,
            literal_f32(&state.m, &[pc])?,
            literal_f32(&state.v, &[pc])?,
            literal_scalar(state.step as f32),
            literal_f32(signals, &[b as i64, nb as i64])?,
        ];
        let outs = execute_untuple(&self.exe, &args)?;
        anyhow::ensure!(outs.len() == 5, "want 5 outputs, got {}", outs.len());
        state.weights.params = literal_to_vec(&outs[0])?;
        state.weights.bn = literal_to_vec(&outs[1])?;
        state.m = literal_to_vec(&outs[2])?;
        state.v = literal_to_vec(&outs[3])?;
        state.step += 1;
        let loss = literal_to_vec(&outs[4])?;
        Ok(loss[0])
    }

    /// Verify against the python-side train golden (one step from init).
    pub fn verify_golden(&self) -> anyhow::Result<()> {
        let init = Weights::load_init(&self.man)?;
        let gin = crate::util::read_f32_file(&self.man.file("train_golden_in")?)?;
        let gout = crate::util::read_f32_file(&self.man.file("train_golden_out")?)?;
        let mut state = TrainState::fresh(init);
        let loss = self.step(&mut state, &gin)?;
        let mut got = Vec::new();
        got.extend(&state.weights.params);
        got.extend(&state.weights.bn);
        got.extend(&state.m);
        got.extend(&state.v);
        got.push(loss);
        anyhow::ensure!(got.len() == gout.len(), "train golden length mismatch");
        let max_diff = got
            .iter()
            .zip(&gout)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        anyhow::ensure!(max_diff < 1e-3, "train golden mismatch: {max_diff}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::artifacts_root;

    fn tiny() -> Option<Manifest> {
        let dir = artifacts_root().join("tiny");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn infer_golden_roundtrip() {
        let Some(man) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        let w = Weights::load_init(&man).unwrap();
        let exe = InferExecutable::load(&rt, &man, &w).unwrap();
        exe.verify_golden().expect("PJRT output matches python golden");
    }

    #[test]
    fn train_golden_roundtrip() {
        let Some(man) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        let exe = TrainExecutable::load(&rt, &man).unwrap();
        exe.verify_golden().expect("train step matches python golden");
    }

    #[test]
    fn infer_rejects_bad_shapes() {
        let Some(man) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        let w = Weights::load_init(&man).unwrap();
        let mut exe = InferExecutable::load(&rt, &man, &w).unwrap();
        assert!(exe.infer_batch(&vec![0.0f32; 5]).is_err());
    }
}
