//! No-op stand-ins for the PJRT runtime, used when the crate is built
//! without the `pjrt` feature (the `xla` crate is absent from the offline
//! registry).  Constructors fail with a clear error; the types exist so
//! every call site compiles unchanged and callers can degrade to the
//! native / accelerator-sim engines.

use super::TrainState;
use crate::infer::{Engine, InferOutput};
use crate::model::{Manifest, Weights};

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} unavailable: built without the `pjrt` cargo feature \
         (the `xla` crate is not in the offline registry); use the \
         native or accel engines instead"
    )
}

/// Stub PJRT client.  `cpu()` always errors, so instances never exist.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Err(unavailable("PJRT runtime"))
    }

    pub fn platform(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Stub inference executable; `load` always errors.
pub struct InferExecutable {
    man: Manifest,
}

impl InferExecutable {
    pub fn load(_rt: &Runtime, _man: &Manifest, _weights: &Weights) -> anyhow::Result<Self> {
        Err(unavailable("PJRT inference executable"))
    }

    pub fn set_weights(&mut self, _weights: &Weights) {}

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    pub fn infer_with_recon(&self, _signals: &[f32]) -> anyhow::Result<(InferOutput, Vec<f32>)> {
        Err(unavailable("PJRT inference executable"))
    }

    pub fn verify_golden(&self) -> anyhow::Result<()> {
        Err(unavailable("PJRT inference executable"))
    }
}

impl Engine for InferExecutable {
    fn name(&self) -> &str {
        "pjrt-stub"
    }
    fn batch_size(&self) -> usize {
        self.man.batch_infer
    }
    fn n_samples(&self) -> usize {
        self.man.n_samples
    }
    fn execute_into(&mut self, _signals: &[f32], _out: &mut InferOutput) -> anyhow::Result<()> {
        Err(unavailable("PJRT inference executable"))
    }
}

/// Stub train-step executable; `load` always errors.
pub struct TrainExecutable {
    man: Manifest,
}

impl TrainExecutable {
    pub fn load(_rt: &Runtime, _man: &Manifest) -> anyhow::Result<Self> {
        Err(unavailable("PJRT train executable"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    pub fn step(&self, _state: &mut TrainState, _signals: &[f32]) -> anyhow::Result<f32> {
        Err(unavailable("PJRT train executable"))
    }

    pub fn verify_golden(&self) -> anyhow::Result<()> {
        Err(unavailable("PJRT train executable"))
    }
}
