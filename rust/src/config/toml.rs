//! TOML-subset parser: `[section]`, `key = value` (string / int / float /
//! bool / flat array), `#` comments.  Enough for this project's configs;
//! rejects anything outside the subset loudly rather than mis-parsing.

use std::collections::BTreeMap;

/// A TOML value in the supported subset.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: section -> key -> value.  Root-level keys live in
/// section "".
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> anyhow::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(
                    !name.is_empty() && !name.contains('['),
                    "line {}: bad section name",
                    lineno + 1
                );
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                anyhow::bail!("line {}: expected 'key = value'", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    anyhow::ensure!(!s.is_empty(), "missing value");
    if let Some(body) = s.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        anyhow::ensure!(!inner.contains('"'), "embedded quotes unsupported");
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("unparseable value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello"   # trailing comment
            i = -42
            f = 2.5
            b = true
            arr = [1, 2, 3]
            [b]
            x = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_int("a", "i"), Some(-42));
        assert_eq!(doc.get_float("a", "f"), Some(2.5));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(
            doc.get("a", "arr"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
        assert_eq!(doc.get_float("b", "x"), Some(0.5));
        assert_eq!(doc.get("zzz", "x"), None);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = TomlDoc::parse("[s]\nv = 3\n").unwrap();
        assert_eq!(doc.get_float("s", "v"), Some(3.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("[s]\nv = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s", "v"), Some("a#b"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("k = \n").is_err());
        assert!(TomlDoc::parse("k = what\n").is_err());
        assert!(TomlDoc::parse("k = \"unterminated\n").is_err());
    }

    #[test]
    fn empty_doc_and_empty_array() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(doc.sections.is_empty());
        let doc = TomlDoc::parse("k = []\n").unwrap();
        assert_eq!(doc.get("", "k"), Some(&TomlValue::Array(vec![])));
    }
}
