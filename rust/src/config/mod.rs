//! Configuration system: a TOML-subset parser (serde/toml unavailable
//! offline, DESIGN.md §7) plus the typed `RunConfig` the CLI and examples
//! consume.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments.  That
//! covers every config this project ships.

pub mod toml;

use crate::accel::AccelConfig;
use crate::coordinator::batcher::BatcherConfig;
use std::time::Duration;

pub use toml::TomlDoc;

/// Top-level run configuration (CLI defaults <- file <- flags).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact variant directory name ("tiny" | "paper").
    pub variant: String,
    /// Engine selection: "native" | "pjrt" | "accel".
    pub engine: String,
    pub batcher: BatcherConfig,
    pub accel: AccelConfig,
    /// Weights stem to load (None = artifact init weights).
    pub weights: Option<String>,
    pub train_steps: usize,
    pub train_snr: f64,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            variant: "tiny".into(),
            engine: "native".into(),
            batcher: BatcherConfig::default(),
            accel: AccelConfig::default(),
            weights: None,
            train_steps: 500,
            train_snr: 20.0,
            seed: 1,
        }
    }
}

impl RunConfig {
    /// Overlay values from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> anyhow::Result<()> {
        if let Some(v) = doc.get_str("run", "variant") {
            self.variant = v.to_string();
        }
        if let Some(v) = doc.get_str("run", "engine") {
            self.engine = v.to_string();
        }
        if let Some(v) = doc.get_str("run", "weights") {
            self.weights = Some(v.to_string());
        }
        if let Some(v) = doc.get_int("run", "seed") {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get_int("batcher", "batch_size") {
            self.batcher.batch_size = v as usize;
        }
        if let Some(v) = doc.get_int("batcher", "queue_capacity") {
            self.batcher.queue_capacity = v as usize;
        }
        if let Some(v) = doc.get_float("batcher", "max_wait_ms") {
            self.batcher.max_wait = Duration::from_micros((v * 1e3) as u64);
        }
        if let Some(v) = doc.get_int("accel", "n_pe") {
            self.accel.n_pe = v as usize;
        }
        if let Some(v) = doc.get_int("accel", "lanes") {
            self.accel.lanes = v as usize;
        }
        if let Some(v) = doc.get_float("accel", "clock_mhz") {
            self.accel.clock_hz = v * 1e6;
        }
        if let Some(v) = doc.get_int("accel", "batch") {
            self.accel.batch = v as usize;
        }
        if let Some(v) = doc.get_int("train", "steps") {
            self.train_steps = v as usize;
        }
        if let Some(v) = doc.get_float("train", "snr") {
            self.train_snr = v;
        }
        anyhow::ensure!(
            matches!(self.engine.as_str(), "native" | "pjrt" | "accel"),
            "unknown engine '{}'",
            self.engine
        );
        Ok(())
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let doc = TomlDoc::parse(&text)?;
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert_eq!(c.variant, "tiny");
        assert_eq!(c.batcher.batch_size, 64);
        assert_eq!(c.accel.n_pe, 32);
    }

    #[test]
    fn toml_overlay() {
        let doc = TomlDoc::parse(
            r#"
            # serving config
            [run]
            variant = "paper"
            engine = "accel"
            seed = 9

            [batcher]
            batch_size = 32
            max_wait_ms = 0.5

            [accel]
            n_pe = 16
            clock_mhz = 300.0

            [train]
            steps = 100
            snr = 30.0
            "#,
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.variant, "paper");
        assert_eq!(c.engine, "accel");
        assert_eq!(c.seed, 9);
        assert_eq!(c.batcher.batch_size, 32);
        assert_eq!(c.batcher.max_wait, Duration::from_micros(500));
        assert_eq!(c.accel.n_pe, 16);
        assert_eq!(c.accel.clock_hz, 300.0e6);
        assert_eq!(c.train_steps, 100);
        assert_eq!(c.train_snr, 30.0);
    }

    #[test]
    fn shipped_example_config_loads() {
        // keep configs/serve.toml honest
        let mut dir = std::env::current_dir().unwrap();
        loop {
            let cand = dir.join("configs").join("serve.toml");
            if cand.exists() {
                let c = RunConfig::from_file(&cand).unwrap();
                assert_eq!(c.variant, "paper");
                assert_eq!(c.engine, "pjrt");
                assert_eq!(c.accel.n_pe, 32);
                return;
            }
            if !dir.pop() {
                return; // not found (e.g. packaged build) — skip
            }
        }
    }

    #[test]
    fn rejects_unknown_engine() {
        let doc = TomlDoc::parse("[run]\nengine = \"gpu\"\n").unwrap();
        let mut c = RunConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }
}
