//! Benchmark harness (criterion substitute, DESIGN.md §7): warmup,
//! adaptive iteration count, outlier-robust statistics and comparison
//! tables.  All `cargo bench` targets (`harness = false`) are built on
//! this.

use crate::util::stats;
use crate::util::Timer;

/// Result of benchmarking one case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p99_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
    /// Throughput given a per-iteration item count.
    pub fn items_per_s(&self, items: usize) -> f64 {
        items as f64 / self.mean_s
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Target total measurement time.
    pub target_s: f64,
    /// Warmup time before measuring.
    pub warmup_s: f64,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            target_s: 1.0,
            warmup_s: 0.2,
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Fast config for CI / smoke runs (honours `UIVIM_BENCH_FAST=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        BenchConfig {
            target_s: 0.1,
            warmup_s: 0.02,
            min_iters: 2,
            max_iters: 100,
        }
    } else {
        BenchConfig::default()
    }
}

/// Benchmark a closure.  The closure is the measured unit; per-iteration
/// samples feed robust stats.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup + per-iteration cost estimate.
    let warm = Timer::start();
    let mut warm_iters = 0usize;
    while warm.elapsed_s() < cfg.warmup_s || warm_iters < 1 {
        f();
        warm_iters += 1;
        if warm_iters >= cfg.max_iters {
            break;
        }
    }
    let est = warm.elapsed_s() / warm_iters as f64;
    let iters = ((cfg.target_s / est.max(1e-9)) as usize)
        .clamp(cfg.min_iters, cfg.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        median_s: stats::median(&samples),
        std_s: stats::std(&samples),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        p99_s: stats::percentile(&samples, 99.0),
    }
}

/// Print a standard results table for a set of bench results.
pub fn print_results(title: &str, results: &[BenchResult]) {
    use crate::metrics::report::Table;
    let mut t = Table::new(&["case", "iters", "mean", "median", "std", "p99"]);
    for r in results {
        t.row(&[
            r.name.clone(),
            r.iters.to_string(),
            fmt_time(r.mean_s),
            fmt_time(r.median_s),
            fmt_time(r.std_s),
            fmt_time(r.p99_s),
        ]);
    }
    println!("\n== {title} ==\n{}", t.to_text());
}

/// Human-friendly time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// One machine-readable bench record for the cross-PR perf trajectory
/// (`BENCH_<bench>.json` at the repository root).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Items per second (iterations/s when `items == 1`).
    pub throughput: f64,
}

impl BenchRecord {
    /// Record from a harness result; `items` is the per-iteration item
    /// count the throughput is reported in (1 = iterations/s).
    pub fn from_result(r: &BenchResult, items: usize) -> BenchRecord {
        BenchRecord {
            name: r.name.clone(),
            p50_us: r.median_s * 1e6,
            p99_us: r.p99_s * 1e6,
            throughput: r.items_per_s(items),
        }
    }
}

/// The repository root (one level above this crate's manifest) — where
/// the `BENCH_*.json` trajectory files live.
pub fn repo_root() -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest
        .parent()
        .unwrap_or(manifest)
        .to_path_buf()
}

/// Write `BENCH_<bench>.json` at the repository root: an array of
/// `{name, p50_us, p99_us, throughput}` objects, so the perf trajectory
/// is diffable across PRs.  Returns the path written.
pub fn write_bench_json(
    bench_name: &str,
    records: &[BenchRecord],
) -> std::io::Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            crate::json_obj! {
                "name" => r.name.clone(),
                "p50_us" => r.p50_us,
                "p99_us" => r.p99_us,
                "throughput" => r.throughput,
            }
        })
        .collect();
    let doc = crate::json_obj! {
        "bench" => bench_name,
        "results" => rows,
    };
    let path = repo_root().join(format!("BENCH_{bench_name}.json"));
    std::fs::write(&path, doc.to_string_pretty())?;
    Ok(path)
}

/// Read a `BENCH_*.json` file back into records (the inverse of
/// [`write_bench_json`]; tolerant of extra fields).
pub fn read_bench_json(path: &std::path::Path) -> anyhow::Result<Vec<BenchRecord>> {
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
    let arr = j
        .get("results")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{}: no 'results' array", path.display()))?;
    Ok(arr
        .iter()
        .map(|r| BenchRecord {
            name: r.get("name").as_str().unwrap_or("").to_string(),
            p50_us: r.get("p50_us").as_f64().unwrap_or(0.0),
            p99_us: r.get("p99_us").as_f64().unwrap_or(0.0),
            throughput: r.get("throughput").as_f64().unwrap_or(0.0),
        })
        .collect())
}

/// Compare a fresh bench run against a committed baseline: any case
/// whose p50 regressed by more than `max_regress` (0.20 = +20%) is a
/// failure.  Baseline records with `p50_us == 0` are **unmeasured**
/// sentinels (committed before a toolchain was available, or synthetic
/// rows like speedup factors): each gets an explicit `unmeasured`
/// verdict row and the report ends with a `N of M cases unmeasured`
/// count.  Cases missing from either side are skipped — but if not a
/// single baseline row was actually compared (all sentinels, all
/// missing/renamed, or an empty baseline) the gate fails outright
/// instead of vacuously passing.  Returns the human-readable comparison
/// table; `Err` carries the same table plus the offending cases.
pub fn compare_bench_records(
    baseline: &[BenchRecord],
    current: &[BenchRecord],
    max_regress: f64,
) -> anyhow::Result<String> {
    use crate::metrics::report::Table;
    let mut t = Table::new(&["case", "baseline p50", "current p50", "delta", "verdict"]);
    let mut regressions = Vec::new();
    let mut unmeasured = 0usize;
    let mut measured = 0usize;
    for b in baseline {
        if b.p50_us <= 0.0 {
            unmeasured += 1;
            let cur = current
                .iter()
                .find(|c| c.name == b.name)
                .map(|c| format!("{:.2} us", c.p50_us))
                .unwrap_or_else(|| "-".into());
            t.row(&[
                b.name.clone(),
                "sentinel (0)".into(),
                cur,
                "-".into(),
                "unmeasured".into(),
            ]);
            continue;
        }
        let Some(c) = current.iter().find(|c| c.name == b.name) else {
            t.row(&[
                b.name.clone(),
                format!("{:.2} us", b.p50_us),
                "-".into(),
                "-".into(),
                "missing (skipped)".into(),
            ]);
            continue;
        };
        measured += 1;
        let delta = c.p50_us / b.p50_us - 1.0;
        let regressed = delta > max_regress;
        t.row(&[
            b.name.clone(),
            format!("{:.2} us", b.p50_us),
            format!("{:.2} us", c.p50_us),
            format!("{:+.1}%", delta * 100.0),
            if regressed { "REGRESSED" } else { "ok" }.into(),
        ]);
        if regressed {
            regressions.push(format!(
                "{}: p50 {:.2} us -> {:.2} us ({:+.1}% > allowed {:+.1}%)",
                b.name,
                b.p50_us,
                c.p50_us,
                delta * 100.0,
                max_regress * 100.0
            ));
        }
    }
    // Cases with no baseline row are not gated, but surfacing them keeps
    // "add a bench case" honest about also committing its baseline.
    for c in current {
        if !baseline.iter().any(|b| b.name == c.name) {
            t.row(&[
                c.name.clone(),
                "-".into(),
                format!("{:.2} us", c.p50_us),
                "-".into(),
                "new (no baseline)".into(),
            ]);
        }
    }
    let mut report = t.to_text();
    if unmeasured > 0 {
        report.push_str(&format!(
            "\n{unmeasured} of {} cases unmeasured (p50 == 0 sentinel baselines)",
            baseline.len()
        ));
    }
    // Not one real comparison happened (every baseline row was a
    // sentinel, missing from the current run, or the baseline is empty):
    // the gate must fail loudly, never vacuously pass.
    if measured == 0 {
        anyhow::bail!(
            "{report}\nzero measured baseline comparisons ({unmeasured} unmeasured sentinels, \
             {} missing/renamed of {} baseline cases) — the gate would vacuously pass; run \
             `UIVIM_BENCH_FAST=1 cargo bench` and commit the emitted BENCH_*.json as the \
             measured baseline",
            baseline.len() - unmeasured,
            baseline.len()
        );
    }
    if regressions.is_empty() {
        Ok(report)
    } else {
        anyhow::bail!(
            "{report}\np50 regressions beyond the budget:\n  {}",
            regressions.join("\n  ")
        )
    }
}

/// [`compare_bench_records`] over two `BENCH_*.json` files.
pub fn compare_bench_files(
    baseline: &std::path::Path,
    current: &std::path::Path,
    max_regress: f64,
) -> anyhow::Result<String> {
    let b = read_bench_json(baseline)?;
    let c = read_bench_json(current)?;
    compare_bench_records(&b, &c, max_regress)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let cfg = BenchConfig {
            target_s: 0.05,
            warmup_s: 0.005,
            min_iters: 3,
            max_iters: 50,
        };
        let r = bench("sleep", &cfg, || {
            std::thread::sleep(std::time::Duration::from_micros(500))
        });
        assert!(r.mean_s >= 400e-6, "mean {}", r.mean_s);
        assert!(r.iters >= 3);
        assert!(r.median_s > 0.0 && r.p99_s >= r.median_s);
    }

    #[test]
    fn adaptive_iters_bounded() {
        let cfg = BenchConfig {
            target_s: 0.02,
            warmup_s: 0.002,
            min_iters: 2,
            max_iters: 64,
        };
        let r = bench("fast", &cfg, || {
            black_box(1 + 1);
        });
        assert!(r.iters <= 64 && r.iters >= 2);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_record_converts_units() {
        let r = BenchResult {
            name: "case".into(),
            iters: 10,
            mean_s: 0.002,
            median_s: 0.001,
            std_s: 0.0,
            min_s: 0.0009,
            p99_s: 0.004,
        };
        let rec = BenchRecord::from_result(&r, 500);
        assert_eq!(rec.name, "case");
        assert!((rec.p50_us - 1000.0).abs() < 1e-9);
        assert!((rec.p99_us - 4000.0).abs() < 1e-9);
        assert!((rec.throughput - 250_000.0).abs() < 1e-6);
    }

    #[test]
    fn repo_root_is_above_the_crate() {
        let root = repo_root();
        assert!(root.join("rust").exists() || root.exists());
    }

    fn rec(name: &str, p50: f64) -> BenchRecord {
        BenchRecord {
            name: name.into(),
            p50_us: p50,
            p99_us: p50 * 2.0,
            throughput: 1.0,
        }
    }

    #[test]
    fn compare_flags_only_regressions_beyond_budget() {
        let baseline = vec![rec("a", 100.0), rec("b", 100.0), rec("c", 0.0), rec("gone", 5.0)];
        // a: +10% (ok), b: +50% (regressed), c: unmeasured (skipped),
        // gone: missing from current (skipped), new: not in baseline
        let current = vec![rec("a", 110.0), rec("b", 150.0), rec("c", 9.0), rec("new", 1.0)];
        let err = compare_bench_records(&baseline, &current, 0.20).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("b: p50"), "{msg}");
        assert!(!msg.contains("a: p50"), "{msg}");
        assert!(!msg.contains("gone: p50"), "{msg}");
        // within budget passes and reports every case
        let ok = compare_bench_records(&baseline, &current, 0.60).unwrap();
        assert!(ok.contains("unmeasured") && ok.contains("+50.0%"), "{ok}");
    }

    /// ISSUE #5: sentinel rows get an explicit `unmeasured` verdict and
    /// the report ends with the `N of M cases unmeasured` count.
    #[test]
    fn unmeasured_rows_get_verdict_and_trailing_count() {
        let baseline = vec![rec("a", 100.0), rec("b", 0.0), rec("c", 0.0)];
        let current = vec![rec("a", 100.0), rec("b", 9.0), rec("c", 5.0)];
        let report = compare_bench_records(&baseline, &current, 0.20).unwrap();
        assert!(report.contains("unmeasured"), "{report}");
        assert!(report.contains("sentinel (0)"), "{report}");
        assert!(
            report.contains("2 of 3 cases unmeasured"),
            "missing trailing count: {report}"
        );
    }

    /// ISSUE #5: a gate run that performs zero real comparisons must
    /// fail — previously an all-sentinel baseline vacuously passed.
    #[test]
    fn zero_measured_comparisons_fail_the_gate() {
        // all sentinels
        let baseline = vec![rec("a", 0.0), rec("b", 0.0)];
        let current = vec![rec("a", 10.0), rec("b", 20.0)];
        let err = compare_bench_records(&baseline, &current, 0.20).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("zero measured baseline comparisons"), "{msg}");
        assert!(msg.contains("2 unmeasured sentinels"), "{msg}");
        assert!(msg.contains("2 of 2 cases unmeasured"), "{msg}");
        // measured rows all renamed away + a sentinel: still zero
        // comparisons, still a failure (the renamed-case hole)
        let renamed = vec![rec("old_name", 100.0), rec("b", 0.0)];
        let err = compare_bench_records(&renamed, &current, 0.20).unwrap_err();
        assert!(
            err.to_string().contains("zero measured baseline comparisons"),
            "{err}"
        );
        // empty baseline: nothing compared, fail
        assert!(compare_bench_records(&[], &current, 0.20).is_err());
        // one measured comparison is enough to disarm the guard
        let mixed = vec![rec("a", 10.0), rec("b", 0.0)];
        assert!(compare_bench_records(&mixed, &current, 0.20).is_ok());
    }

    /// The armed CI gate end to end at the file level (exactly what
    /// `repro bench-diff --baseline … --current …` runs): a synthetic
    /// baseline/current pair with a 21% p50 regression must FAIL, and
    /// the same pair under a 25% budget must pass — proving the gate
    /// actually bites once baselines carry real (non-zero) p50s.
    #[test]
    fn bench_diff_gate_fails_a_21_percent_regression() {
        let dir = std::env::temp_dir().join("uivim_bench_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, recs: &[BenchRecord]| -> std::path::PathBuf {
            let rows: Vec<crate::util::json::Json> = recs
                .iter()
                .map(|r| {
                    crate::json_obj! {
                        "name" => r.name.clone(),
                        "p50_us" => r.p50_us,
                        "p99_us" => r.p99_us,
                        "throughput" => r.throughput,
                    }
                })
                .collect();
            let doc = crate::json_obj! { "bench" => "gate", "results" => rows };
            let path = dir.join(name);
            std::fs::write(&path, doc.to_string_pretty()).unwrap();
            path
        };
        let baseline = write(
            "baseline.json",
            &[rec("serve_batch16_shards4", 100.0), rec("steady", 50.0)],
        );
        let current = write(
            "current.json",
            &[rec("serve_batch16_shards4", 121.0), rec("steady", 50.0)],
        );
        let err = compare_bench_files(&baseline, &current, 0.20).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("serve_batch16_shards4") && msg.contains("REGRESSED"),
            "the gate must name the regressed case: {msg}"
        );
        assert!(!msg.contains("steady: p50"), "{msg}");
        // the same pair under a looser budget passes
        assert!(compare_bench_files(&baseline, &current, 0.25).is_ok());
    }

    #[test]
    fn compare_roundtrips_through_json_files() {
        let dir = std::env::temp_dir().join("uivim_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let records = vec![rec("x", 10.0), rec("y", 20.0)];
        let rows: Vec<crate::util::json::Json> = records
            .iter()
            .map(|r| {
                crate::json_obj! {
                    "name" => r.name.clone(),
                    "p50_us" => r.p50_us,
                    "p99_us" => r.p99_us,
                    "throughput" => r.throughput,
                }
            })
            .collect();
        let doc = crate::json_obj! { "bench" => "t", "results" => rows };
        let base = dir.join("base.json");
        std::fs::write(&base, doc.to_string_pretty()).unwrap();
        let back = read_bench_json(&base).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "x");
        assert!((back[1].p50_us - 20.0).abs() < 1e-9);
        assert!(compare_bench_files(&base, &base, 0.2).is_ok());
        assert!(compare_bench_files(&dir.join("nope.json"), &base, 0.2).is_err());
    }
}
