//! Integration: the work-stealing deque dispatcher under real threads at
//! fleet width — 16 shards, burst traffic, forced steals, leased request
//! buffers, shutdown while loaded.  The deterministic interleaving
//! coverage lives in `testing::sched` (virtual time, table-driven); this
//! file is the soak that makes the same protocol earn it on a real
//! scheduler, and CI runs both in the `coordinator-stress` job.
//!
//! Runs on the deterministic in-tree fixture, so nothing here skips when
//! the Python-exported artifacts are absent.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, VoxelRequest};
use uivim::infer::registry::{factory, EngineOpts};
use uivim::infer::{Engine, InferOutput};
use uivim::ivim::synth::synth_dataset;
use uivim::testing::fixture;

/// Wraps an engine with a fixed per-batch delay — a deterministic "slow
/// shard" whose deque backlog the fast shards must steal.
struct SlowEngine {
    inner: Box<dyn Engine>,
    delay: Duration,
}

impl Engine for SlowEngine {
    fn name(&self) -> &str {
        "slow-wrapper"
    }
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn n_samples(&self) -> usize {
        self.inner.n_samples()
    }
    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.inner.execute_into(signals, out)
    }
}

/// 16 shards, one fast and fifteen slow: the dispatcher's p2c spreads
/// the burst across all deques, the slow shards each sit on one batch
/// for 20 ms, and the fast shard — its own deque drained in
/// microseconds — must steal the rest of the fleet's backlog.  Every
/// request is answered exactly once, the claim counters partition the
/// batch total, and steals are guaranteed by construction (the fast
/// shard serves far more batches than its own deque ever received).
#[test]
fn soak_16_shards_burst_forces_steals_and_loses_nothing() {
    let shards = 16usize;
    let batch = 4usize;
    let n = 1600usize; // 400 batches
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.queue_capacity = n + 1;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let built = Arc::new(AtomicUsize::new(0));
    let inner = factory(
        "native",
        man.clone(),
        w,
        EngineOpts {
            batch: Some(batch),
            ..Default::default()
        },
    )
    .unwrap();
    let coord = Coordinator::start(cfg, move || {
        // the first engine constructed is the fast one; the other 15
        // serve a batch per 20 ms
        let delay = if built.fetch_add(1, Ordering::SeqCst) == 0 {
            Duration::ZERO
        } else {
            Duration::from_millis(20)
        };
        Ok(Box::new(SlowEngine {
            inner: inner()?,
            delay,
        }) as Box<dyn Engine>)
    })
    .unwrap();

    let ds = synth_dataset(n, &man.bvalues, 20.0, 161);
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let mut lease = coord.lease();
            lease.copy_from(ds.voxel(i));
            coord
                .submit_leased(i as u64, lease)
                .expect("queue sized for the burst")
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("request {i} lost under stealing: {e}"));
        assert_eq!(resp.id, i as u64, "response routed to the wrong caller");
    }

    let snap = coord.snapshot();
    assert_eq!(snap.responses, n as u64);
    assert_eq!(snap.rejected, 0);
    assert_eq!(coord.queue_depth(), 0);
    // exactly-once claim accounting across the whole fleet
    assert_eq!(
        snap.local_batches() + snap.stolen_batches(),
        snap.batches,
        "claims must partition batches: {:?}",
        snap.per_shard
    );
    let by_shard: u64 = snap.per_shard.iter().map(|s| s.responses).sum();
    assert_eq!(by_shard, n as u64, "shard counters partition responses");
    // with 15 shards pinned at 20 ms/batch and ~25 batches p2c'd onto
    // each deque, the fast shard can only have served the majority it
    // did by stealing — zero steals would mean the backlog waited on
    // stalled shards, the exact failure this dispatcher removes
    assert!(
        snap.stolen_batches() > 0,
        "a skewed fleet must steal: {:?}",
        snap.per_shard
    );
    // the deques are empty once everything is answered
    assert!(snap.per_shard.iter().all(|s| s.deque_depth == 0));
    coord.shutdown();
}

/// Concurrent leased clients reach a steady state where a second full
/// wave of traffic allocates **zero** new request buffers — the lease
/// slab's capacity-stability signature under real contention.
#[test]
fn leased_clients_hit_a_stable_high_water_mark() {
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, 8, 4);
    cfg.batcher.queue_capacity = 100_000;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let coord = Arc::new(
        Coordinator::start(
            cfg,
            factory(
                "native",
                man.clone(),
                w,
                EngineOpts {
                    batch: Some(8),
                    ..Default::default()
                },
            )
            .unwrap(),
        )
        .unwrap(),
    );

    let wave = |offset: u64| {
        std::thread::scope(|s| {
            for c in 0..4u64 {
                let coord = Arc::clone(&coord);
                let man = man.clone();
                s.spawn(move || {
                    let ds = synth_dataset(100, &man.bvalues, 20.0, 500 + c);
                    for i in 0..100u64 {
                        let mut lease = coord.lease();
                        lease.copy_from(ds.voxel(i as usize));
                        let rx = coord
                            .submit_leased(offset + c * 100 + i, lease)
                            .expect("capacity sized");
                        rx.recv_timeout(Duration::from_secs(30)).expect("response");
                    }
                });
            }
        });
    };

    wave(0);
    let hw = coord.lease_high_water();
    assert!(hw >= 1, "wave 1 populated the slab");
    wave(1000);
    assert_eq!(
        coord.lease_high_water(),
        hw,
        "wave 2 must reuse wave 1's buffers — the slab grew under load"
    );
    let snap = coord.snapshot();
    assert_eq!(snap.responses, 800);
    assert!(snap.pooled_requests >= 1);
}

/// Shutdown while 16 shards are mid-burst: every admitted request is
/// still answered — the close-then-keep-claiming (and keep-stealing)
/// drain contract at fleet width.
#[test]
fn shutdown_under_load_answers_every_admitted_request() {
    let shards = 16usize;
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, 8, shards);
    cfg.batcher.queue_capacity = 100_000;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(
        cfg,
        factory(
            "native",
            man.clone(),
            w,
            EngineOpts {
                batch: Some(8),
                ..Default::default()
            },
        )
        .unwrap(),
    )
    .unwrap();
    let n = 800;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 162);
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            coord
                .submit(VoxelRequest {
                    id: i as u64,
                    signals: ds.voxel(i).to_vec(),
                })
                .unwrap()
        })
        .collect();
    // tear down while most responses are still in flight
    coord.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("request {i} dropped during shutdown: {e}"));
        assert_eq!(resp.id, i as u64);
    }
}
