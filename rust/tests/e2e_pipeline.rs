//! Integration: the full pipeline across all three engines — train via
//! PJRT, then verify every engine (native f32, PJRT/XLA, accelerator
//! simulator) agrees on the trained model and produces calibrated
//! uncertainty, end to end.

use uivim::accel::{AccelConfig, AccelSimulator, Scheme};
use uivim::experiments::load_manifest;
use uivim::infer::native::NativeEngine;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Weights;
use uivim::runtime::{InferExecutable, Runtime};
use uivim::train::{train, TrainConfig};

fn setup() -> Option<(uivim::model::Manifest, Runtime)> {
    let man = load_manifest("tiny").ok()?;
    let rt = Runtime::cpu().ok()?;
    Some((man, rt))
}

#[test]
fn train_then_all_engines_agree() {
    let Some((man, rt)) = setup() else { return };
    // Train a short run so predictions carry signal.
    let rep = train(
        &rt,
        &man,
        &TrainConfig {
            steps: 120,
            snr: 20.0,
            seed: 3,
            log_every: 0,
            early_stop_rel: 0.0,
        },
        None,
    )
    .expect("training");
    assert!(rep.final_loss() < rep.initial_loss());
    let w: Weights = rep.final_weights;

    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 99);

    let mut native = NativeEngine::new(&man, &w).unwrap();
    let mut pjrt = InferExecutable::load(&rt, &man, &w).unwrap();
    let mut sim = AccelSimulator::new(
        &man,
        &w,
        AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        },
        Scheme::BatchLevel,
    )
    .unwrap();

    let a = native.infer_batch(&ds.signals).unwrap();
    let b = pjrt.infer_batch(&ds.signals).unwrap();
    let c = sim.infer_batch(&ds.signals).unwrap();

    for p in Param::ALL {
        let (lo, hi) = p.range();
        let span = hi - lo;
        for s in 0..a.n_samples {
            for v in 0..a.batch {
                let f1 = a.get(p, s, v) as f64;
                let f2 = b.get(p, s, v) as f64;
                let f3 = c.get(p, s, v) as f64;
                // native vs PJRT: f32 round-off only
                assert!(
                    (f1 - f2).abs() < span * 2e-3,
                    "{p:?} native {f1} vs pjrt {f2}"
                );
                // accelerator: Q4.12 + PLAN sigmoid tolerance
                assert!(
                    (f1 - f3).abs() < span * 0.06,
                    "{p:?} native {f1} vs accel {f3}"
                );
            }
        }
    }
}

#[test]
fn trained_model_beats_untrained_on_reconstruction_params() {
    let Some((man, rt)) = setup() else { return };
    let rep = train(
        &rt,
        &man,
        &TrainConfig {
            steps: 200,
            snr: 30.0,
            seed: 4,
            log_every: 0,
            early_stop_rel: 0.0,
        },
        None,
    )
    .unwrap();
    let trained = rep.final_weights;
    let init = Weights::load_init(&man).unwrap();

    let ds = synth_dataset(512, &man.bvalues, 30.0, 55);
    let rmse_with = |w: &Weights| {
        let mut eng = NativeEngine::new(&man, w).unwrap();
        let outs = uivim::experiments::fig67::run_batches(&mut eng, &ds).unwrap();
        // D* and f dominate the signal reconstruction; compare their
        // combined normalised RMSE.
        Param::ALL
            .iter()
            .map(|&p| {
                let (lo, hi) = p.range();
                uivim::metrics::rmse_by_param(&outs, &ds, p) / (hi - lo)
            })
            .sum::<f64>()
    };
    let r_trained = rmse_with(&trained);
    let r_init = rmse_with(&init);
    assert!(
        r_trained < r_init,
        "training must improve parameter recovery: {r_trained} vs {r_init}"
    );
}

#[test]
fn uncertainty_is_calibrated_after_training() {
    let Some((man, rt)) = setup() else { return };
    let rep = train(
        &rt,
        &man,
        &TrainConfig {
            steps: 200,
            snr: 20.0,
            seed: 5,
            log_every: 0,
            early_stop_rel: 0.0,
        },
        None,
    )
    .unwrap();
    let mut eng = NativeEngine::new(&man, &rep.final_weights).unwrap();

    // Noisier inputs must yield higher average uncertainty (Fig. 7 shape).
    let noisy = synth_dataset(512, &man.bvalues, 5.0, 66);
    let clean = synth_dataset(512, &man.bvalues, 50.0, 66);
    let o_noisy = uivim::experiments::fig67::run_batches(&mut eng, &noisy).unwrap();
    let o_clean = uivim::experiments::fig67::run_batches(&mut eng, &clean).unwrap();
    let u_noisy = uivim::metrics::mean_relative_uncertainty_all(&o_noisy, noisy.len());
    let u_clean = uivim::metrics::mean_relative_uncertainty_all(&o_clean, clean.len());
    assert!(
        u_clean < u_noisy,
        "uncertainty must shrink with less noise: {u_clean} vs {u_noisy}"
    );
}
