//! Integration: the streaming 3-D volume pipeline (ISSUE #7) —
//! per-voxel bit-identity against the direct engine path, the
//! peak-memory capacity signature (lease high-water independent of
//! volume depth), and backpressure under a deliberately tiny admission
//! queue.
//!
//! Runs on the deterministic in-tree fixture, so nothing here skips when
//! the Python-exported artifacts are absent.

use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig};
use uivim::infer::registry::{self, factory, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Manifest;
use uivim::testing::fixture;
use uivim::volume::scenario::Corruption;
use uivim::volume::stream::{stream_volume, volume_metrics, StreamConfig};
use uivim::volume::VolumeSpec;

fn start(batch: usize, capacity: usize, shards: usize) -> (Coordinator, Manifest) {
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.queue_capacity = capacity;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let opts = EngineOpts {
        batch: Some(batch),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        factory("native", man.clone(), w, opts).expect("known engine"),
    )
    .expect("coordinator start");
    (coord, man)
}

fn spec(man: &Manifest, dim: (usize, usize, usize), seed: u64) -> VolumeSpec {
    VolumeSpec {
        dim,
        bvals: man.bvalues.clone(),
        snr: 20.0,
        seed,
    }
}

/// Every voxel of the assembled maps — mean, std, relative, truth —
/// equals the direct (no coordinator, no streaming) engine run on the
/// equivalent flat dataset, bit for bit, despite sharded dispatch and
/// out-of-order completion.
#[test]
fn streamed_maps_match_direct_engine_bit_for_bit() {
    let (coord, man) = start(8, 1_000, 2);
    let dim = (3usize, 3usize, 4usize);
    let n = dim.0 * dim.1 * dim.2;
    let s = spec(&man, dim, 77);
    let vol = stream_volume(
        &coord,
        &s,
        Corruption::Clean,
        &StreamConfig {
            slices_in_flight: 2,
            ..Default::default()
        },
    )
    .expect("stream");
    coord.shutdown();

    // Direct path: same seed ⇒ same voxels (the SliceStream contract).
    let (man2, w) = fixture::tiny_fixture();
    let ds = synth_dataset(n, &man2.bvalues, 20.0, 77);
    let mut engine = registry::build("native", &man2, &w, &EngineOpts::default()).unwrap();
    let outs = uivim::experiments::fig67::run_batches(engine.as_mut(), &ds).unwrap();

    let mut voxel = 0usize;
    for out in &outs {
        for v in 0..out.batch {
            if voxel >= n {
                break;
            }
            for p in Param::ALL {
                let maps = vol.param(p);
                assert_eq!(
                    maps.mean.data[voxel],
                    out.mean(p, v),
                    "mean diverged at voxel {voxel} {p:?}"
                );
                assert_eq!(
                    maps.std.data[voxel],
                    out.std(p, v),
                    "std diverged at voxel {voxel} {p:?}"
                );
                assert_eq!(
                    maps.relative.data[voxel],
                    out.relative_uncertainty(p, v),
                    "relative diverged at voxel {voxel} {p:?}"
                );
                assert_eq!(
                    maps.truth.data[voxel],
                    ds.truth[voxel].get(p),
                    "truth diverged at voxel {voxel} {p:?}"
                );
            }
            voxel += 1;
        }
    }
    assert_eq!(voxel, n, "every voxel compared");
    // And the reduced metrics agree with the metrics-module reductions.
    let m = volume_metrics(&vol);
    for p in Param::ALL {
        assert_eq!(
            m.rmse[p.index()],
            uivim::metrics::rmse_by_param(&outs, &ds, p)
        );
        assert_eq!(
            m.uncertainty[p.index()],
            uivim::metrics::mean_relative_uncertainty(&outs, p, n)
        );
        assert_eq!(
            m.calibration[p.index()],
            uivim::metrics::calibration(&outs, &ds, p)
        );
    }
}

/// ISSUE #7 peak-memory guard: the lease slab's `created()` high-water
/// mark is a function of the backpressure window, NOT of volume depth.
/// The slab is warmed to its provable ceiling (the admission-queue
/// window — the driver can never hold more un-reclaimed leases than
/// that), then a shallow and a 4x-deeper volume stream through the
/// same coordinator and the counter must not move by a single buffer.
/// Deterministic: growth would require more concurrent leases than the
/// admission gate admits, regardless of thread timing.
#[test]
fn lease_high_water_is_independent_of_volume_depth() {
    let nv = 4 * 4; // slice voxels
    let inflight = 2;
    let window = inflight * nv + 1; // == queue capacity below
    let (coord, man) = start(8, window, 2);
    // Warm the slab to the ceiling: `window` leases held at once.
    let warm_leases: Vec<_> = (0..window).map(|_| coord.lease()).collect();
    drop(warm_leases);
    let warm = coord.lease_high_water();
    assert_eq!(warm, window, "warm-up fills the slab to the window");
    let scfg = StreamConfig {
        slices_in_flight: inflight,
        ..Default::default()
    };
    let shallow = spec(&man, (4, 4, 2), 5);
    stream_volume(&coord, &shallow, Corruption::Clean, &scfg).expect("shallow");
    assert_eq!(coord.lease_high_water(), warm, "shallow volume stayed flat");
    // A 4x-deeper volume must not move the high-water either: peak
    // memory is set by the backpressure window, not the slice count.
    let deep = spec(&man, (4, 4, 8), 6);
    let vol = stream_volume(&coord, &deep, Corruption::Clean, &scfg).expect("deep");
    assert_eq!(
        coord.lease_high_water(),
        warm,
        "deeper volume allocated fresh lease buffers — streaming is not \
         holding a stable high-water mark"
    );
    assert_eq!(vol.stats.lease_high_water, warm);
    coord.shutdown();
}

/// Backpressure under a queue that holds one slice plus one voxel: the
/// admission gate stalls-and-drains instead of overflowing, so the
/// coordinator never rejects a request and the volume still completes.
/// With `slices_in_flight = 1`, every slice after the first is a
/// guaranteed stall, so the stall counter must be visibly non-zero.
#[test]
fn tiny_queue_backpressures_without_rejection() {
    let nv = 4 * 4;
    let (coord, man) = start(8, nv + 1, 2);
    let s = spec(&man, (4, 4, 6), 9);
    let vol = stream_volume(
        &coord,
        &s,
        Corruption::Clean,
        &StreamConfig {
            slices_in_flight: 1,
            ..Default::default()
        },
    )
    .expect("backpressured stream must still complete");
    let snap = coord.snapshot();
    assert_eq!(snap.rejected, 0, "admission gate must prevent rejections");
    assert_eq!(snap.responses, s.n_voxels() as u64);
    assert!(
        vol.stats.stalls >= (s.slices() - 1) as u64,
        "in-flight cap 1 stalls every subsequent slice (got {})",
        vol.stats.stalls
    );
    assert_eq!(vol.stats.max_inflight_slices, 1);
    assert!(vol.stats.max_queue_depth <= nv + 1);
    assert_eq!(snap.slices_ingested, s.slices() as u64);
    assert_eq!(snap.volumes_completed, 1);
    assert_eq!(snap.stream_stalls, vol.stats.stalls);
    // The assembled maps are complete: every voxel finite.
    for p in Param::ALL {
        let st = vol.param(p).mean.stats();
        assert_eq!(st.finite, st.total, "{p:?} map has holes");
    }
    coord.shutdown();
}

/// Single-producer invariant regression (ISSUE #9 satellite): while any
/// driver owns the coordinator's slice-admission gate, a concurrent
/// `stream_volume` on the same coordinator must fail fast with an
/// explicit error — not race the gate into over-admission.  Once the
/// guard drops, streaming works again, so strictly-sequential volumes
/// (the supported pattern) are unaffected.
#[test]
fn concurrent_stream_drivers_are_rejected_not_raced() {
    let (coord, man) = start(8, 1_000, 2);
    let s = spec(&man, (4, 4, 2), 31);
    let scfg = StreamConfig::default();

    // Simulate a driver mid-volume by holding the guard directly.
    let guard = coord.stream_driver_guard().expect("first owner wins");
    let err = stream_volume(&coord, &s, Corruption::Clean, &scfg)
        .expect_err("second driver must be rejected while the gate is owned");
    assert!(
        err.to_string().contains("single-producer"),
        "rejection names the violated invariant: {err}"
    );
    drop(guard);

    // Sequential use — the documented contract — still streams fine,
    // which also proves stream_volume releases its own guard on return.
    let a = stream_volume(&coord, &s, Corruption::Clean, &scfg).expect("after drop");
    let b = stream_volume(&coord, &s, Corruption::Clean, &scfg).expect("sequential reuse");
    assert_eq!(a.n_voxels(), s.n_voxels());
    assert_eq!(b.n_voxels(), s.n_voxels());
    coord.shutdown();
}

/// Corrupted scenarios flow through the same pipeline: extra noise and
/// motion produce complete volumes, and extra noise degrades RMSE
/// relative to the clean run at the same seed.
#[test]
fn corrupted_scenarios_stream_end_to_end() {
    let (coord, man) = start(8, 1_000, 2);
    let s = spec(&man, (4, 4, 2), 21);
    let scfg = StreamConfig {
        slices_in_flight: 2,
        ..Default::default()
    };
    let clean = stream_volume(&coord, &s, Corruption::Clean, &scfg).unwrap();
    let noisy = stream_volume(
        &coord,
        &s,
        Corruption::ExtraNoise { std: 0.5 },
        &scfg,
    )
    .unwrap();
    let moved = stream_volume(&coord, &s, Corruption::Motion { max_shift: 3 }, &scfg).unwrap();
    coord.shutdown();
    let mc = volume_metrics(&clean);
    let mn = volume_metrics(&noisy);
    let mm = volume_metrics(&moved);
    let total = |m: &uivim::volume::stream::StreamedMetrics| {
        Param::ALL
            .iter()
            .map(|&p| {
                let (lo, hi) = p.range();
                m.rmse[p.index()] / (hi - lo)
            })
            .sum::<f64>()
    };
    assert!(
        total(&mn) > total(&mc),
        "heavy extra noise must degrade RMSE: {} vs {}",
        total(&mn),
        total(&mc)
    );
    for m in [&mn, &mm] {
        for p in Param::ALL {
            assert!(m.rmse[p.index()].is_finite());
            assert!(m.uncertainty[p.index()].is_finite());
        }
    }
}
