//! Integration: coordinator under concurrent multi-client load —
//! correctness (every request answered exactly once, right voxel), FIFO
//! fairness, and backpressure accounting.

use std::sync::Arc;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, VoxelRequest};
use uivim::experiments::load_manifest;
use uivim::infer::native::NativeEngine;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Weights;

fn start(batch: usize, capacity: usize) -> Option<(Arc<Coordinator>, uivim::model::Manifest)> {
    let man = load_manifest("tiny").ok()?;
    let man2 = man.clone();
    let mut cfg = CoordinatorConfig::for_batch(man.nb, batch);
    cfg.batcher.queue_capacity = capacity;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg, move || {
        let w = Weights::load_init(&man2)?;
        Ok(Box::new(NativeEngine::with_batch(&man2, &w, batch)?) as Box<dyn Engine>)
    })
    .ok()?;
    Some((Arc::new(coord), man))
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let Some((coord, man)) = start(16, 100_000) else {
        return;
    };
    let n_clients = 4;
    let per_client = 200;

    // Distinguishable voxels: client c voxel i gets a unique id.
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let coord = Arc::clone(&coord);
            let man = man.clone();
            s.spawn(move || {
                let ds = synth_dataset(per_client, &man.bvalues, 20.0, 100 + c as u64);
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| {
                        let id = (c * per_client + i) as u64;
                        (
                            id,
                            coord
                                .submit(VoxelRequest {
                                    id,
                                    signals: ds.voxel(i).to_vec(),
                                })
                                .expect("capacity sized"),
                        )
                    })
                    .collect();
                for (id, rx) in rxs {
                    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
                    assert_eq!(resp.id, id, "response routed to the wrong client");
                    let d = resp.report.get(Param::D);
                    assert!(d.mean >= 0.0 && d.mean <= 0.005);
                    assert!(d.std.is_finite());
                }
            });
        }
    });

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.responses, (n_clients * per_client) as u64);
    assert_eq!(snap.rejected, 0);
    assert_eq!(coord.queue_depth(), 0, "all requests drained");
}

#[test]
fn duplicate_submissions_get_independent_responses() {
    let Some((coord, man)) = start(8, 1000) else {
        return;
    };
    let ds = synth_dataset(1, &man.bvalues, 20.0, 7);
    let sig = ds.voxel(0).to_vec();
    let rx1 = coord
        .submit(VoxelRequest {
            id: 1,
            signals: sig.clone(),
        })
        .unwrap();
    let rx2 = coord
        .submit(VoxelRequest {
            id: 2,
            signals: sig,
        })
        .unwrap();
    let r1 = rx1.recv_timeout(Duration::from_secs(10)).unwrap();
    let r2 = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r1.id, 1);
    assert_eq!(r2.id, 2);
    // identical input voxels -> identical deterministic estimates
    for p in Param::ALL {
        assert_eq!(r1.report.get(p).mean, r2.report.get(p).mean);
    }
}

#[test]
fn metrics_batch_sizes_are_batched_under_burst() {
    let Some((coord, man)) = start(16, 100_000) else {
        return;
    };
    let n = 320;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 8);
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            coord
                .submit(VoxelRequest {
                    id: i as u64,
                    signals: ds.voxel(i).to_vec(),
                })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let snap = coord.metrics().snapshot();
    // burst of 320 into batch-16 -> ideally 20 batches; allow slack for
    // the race between producer and consumer, but far fewer than 320.
    assert!(
        snap.batches <= 120,
        "batching degenerated: {} batches for {n} requests",
        snap.batches
    );
}
