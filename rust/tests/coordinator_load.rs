//! Integration: coordinator under concurrent multi-client load —
//! correctness (every request answered exactly once, right voxel), FIFO
//! fairness, backpressure accounting, and the sharded worker pool under
//! burst traffic (no starved shard, clean shutdown while loaded).
//!
//! Runs on the deterministic in-tree fixture, so nothing here skips when
//! the Python-exported artifacts are absent.

use std::sync::Arc;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, VoxelRequest};
use uivim::infer::registry::{factory, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Manifest;
use uivim::testing::fixture;

fn start(batch: usize, capacity: usize, shards: usize) -> (Arc<Coordinator>, Manifest) {
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.queue_capacity = capacity;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let opts = EngineOpts {
        batch: Some(batch),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        factory("native", man.clone(), w, opts).expect("known engine"),
    )
    .expect("coordinator start");
    (Arc::new(coord), man)
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let (coord, man) = start(16, 100_000, 1);
    let n_clients = 4;
    let per_client = 200;

    // Distinguishable voxels: client c voxel i gets a unique id.
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let coord = Arc::clone(&coord);
            let man = man.clone();
            s.spawn(move || {
                let ds = synth_dataset(per_client, &man.bvalues, 20.0, 100 + c as u64);
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| {
                        let id = (c * per_client + i) as u64;
                        (
                            id,
                            coord
                                .submit(VoxelRequest {
                                    id,
                                    signals: ds.voxel(i).to_vec(),
                                })
                                .expect("capacity sized"),
                        )
                    })
                    .collect();
                for (id, rx) in rxs {
                    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
                    assert_eq!(resp.id, id, "response routed to the wrong client");
                    let d = resp.report.get(Param::D);
                    assert!(d.mean >= 0.0 && d.mean <= 0.005);
                    assert!(d.std.is_finite());
                }
            });
        }
    });

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.responses, (n_clients * per_client) as u64);
    assert_eq!(snap.rejected, 0);
    assert_eq!(coord.queue_depth(), 0, "all requests drained");
}

#[test]
fn duplicate_submissions_get_independent_responses() {
    let (coord, man) = start(8, 1000, 1);
    let ds = synth_dataset(1, &man.bvalues, 20.0, 7);
    let sig = ds.voxel(0).to_vec();
    let rx1 = coord
        .submit(VoxelRequest {
            id: 1,
            signals: sig.clone(),
        })
        .unwrap();
    let rx2 = coord
        .submit(VoxelRequest {
            id: 2,
            signals: sig,
        })
        .unwrap();
    let r1 = rx1.recv_timeout(Duration::from_secs(10)).unwrap();
    let r2 = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r1.id, 1);
    assert_eq!(r2.id, 2);
    // identical input voxels -> identical deterministic estimates
    for p in Param::ALL {
        assert_eq!(r1.report.get(p).mean, r2.report.get(p).mean);
    }
}

#[test]
fn metrics_batch_sizes_are_batched_under_burst() {
    let (coord, man) = start(16, 100_000, 1);
    let n = 320;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 8);
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            coord
                .submit(VoxelRequest {
                    id: i as u64,
                    signals: ds.voxel(i).to_vec(),
                })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let snap = coord.metrics().snapshot();
    // burst of 320 into batch-16 -> ideally 20 batches; allow slack for
    // the race between producer and consumer, but far fewer than 320.
    assert!(
        snap.batches <= 120,
        "batching degenerated: {} batches for {n} requests",
        snap.batches
    );
}

#[test]
fn sharded_burst_all_responses_delivered() {
    let shards = 4;
    let (coord, man) = start(8, 100_000, shards);
    let n_clients = 4;
    let per_client = 250;

    // Concurrent burst from several clients straight into the pool.
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let coord = Arc::clone(&coord);
            let man = man.clone();
            s.spawn(move || {
                let ds = synth_dataset(per_client, &man.bvalues, 20.0, 300 + c as u64);
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| {
                        let id = (c * per_client + i) as u64;
                        (
                            id,
                            coord
                                .submit(VoxelRequest {
                                    id,
                                    signals: ds.voxel(i).to_vec(),
                                })
                                .expect("capacity sized"),
                        )
                    })
                    .collect();
                for (id, rx) in rxs {
                    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
                    assert_eq!(resp.id, id);
                }
            });
        }
    });

    let snap = coord.metrics().snapshot();
    let total = (n_clients * per_client) as u64;
    assert_eq!(snap.responses, total, "every burst request answered");
    assert_eq!(snap.rejected, 0);
    assert_eq!(coord.queue_depth(), 0);

    // Per-shard accounting: responses and batches partition exactly
    // across shards.  (Batch ownership itself is demand-driven under the
    // work-stealing pull dispatcher, so only the totals are
    // deterministic — a fast shard legitimately serves more.)
    assert_eq!(snap.per_shard.len(), shards);
    let by_shard: u64 = snap.per_shard.iter().map(|s| s.responses).sum();
    assert_eq!(by_shard, total, "shard counters must partition responses");
    let batches_by_shard: u64 = snap.per_shard.iter().map(|s| s.batches).sum();
    assert_eq!(
        batches_by_shard, snap.batches,
        "every batch claimed by exactly one shard"
    );
}

#[test]
fn sharded_results_independent_of_shard_count() {
    // The same voxels through 1-shard and 4-shard pools must produce the
    // identical per-voxel estimates: sharding is a scheduling choice.
    let (c1, man) = start(8, 100_000, 1);
    let (c4, _) = start(8, 100_000, 4);
    let n = 96;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 9);
    let collect = |coord: &Coordinator| -> Vec<(f64, f64)> {
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        rxs.into_iter()
            .map(|rx| {
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
                let e = r.report.get(Param::F);
                (e.mean, e.std)
            })
            .collect()
    };
    assert_eq!(collect(&c1), collect(&c4));
}

#[test]
fn clean_shutdown_under_load_answers_every_admitted_request() {
    // Submit a burst and shut down immediately: every admitted request
    // must still be answered (drain), none dropped, all shards joined.
    let (coord, man) = start(16, 100_000, 3);
    let n = 400;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 10);
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            coord
                .submit(VoxelRequest {
                    id: i as u64,
                    signals: ds.voxel(i).to_vec(),
                })
                .unwrap()
        })
        .collect();
    // Tear down while most responses are still in flight.
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => panic!("coordinator uniquely owned here"),
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("request {i} dropped during shutdown: {e}"));
        assert_eq!(resp.id, i as u64);
    }
}
