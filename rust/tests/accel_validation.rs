//! Integration: accelerator simulator validation against the native
//! oracle across noise levels, schemes and PE counts, plus the paper's
//! architectural claims at system level.

use uivim::accel::{AccelConfig, AccelSimulator, Scheme};
use uivim::experiments::load_manifest;
use uivim::infer::native::NativeEngine;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::{Manifest, Weights};
use uivim::testing::fixture;

/// Artifacts when exported, else the deterministic in-tree fixture so
/// the validation suite always runs.
fn setup() -> (Manifest, Weights) {
    match load_manifest("tiny") {
        Ok(man) => {
            let w = Weights::load_init(&man).unwrap();
            (man, w)
        }
        Err(_) => fixture::tiny_fixture(),
    }
}

#[test]
fn quantised_outputs_track_oracle_across_snrs() {
    let (man, w) = setup();
    let mut native = NativeEngine::new(&man, &w).unwrap();
    for (i, snr) in [5.0, 20.0, 50.0].into_iter().enumerate() {
        let ds = synth_dataset(man.batch_infer, &man.bvalues, snr, 200 + i as u64);
        let mut sim = AccelSimulator::new(
            &man,
            &w,
            AccelConfig {
                batch: man.batch_infer,
                ..Default::default()
            },
            Scheme::BatchLevel,
        )
        .unwrap();
        let a = native.infer_batch(&ds.signals).unwrap();
        let b = sim.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            let tol = (hi - lo) * 0.06;
            for s in 0..a.n_samples {
                for v in 0..a.batch {
                    let d = (a.get(p, s, v) - b.get(p, s, v)).abs() as f64;
                    assert!(d <= tol, "snr {snr} {p:?}: {d} > {tol}");
                }
            }
        }
    }
}

#[test]
fn pe_count_does_not_change_results() {
    // Parallelism is a scheduling choice; numerics must be invariant.
    let (man, w) = setup();
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 300);
    let run = |n_pe: usize| {
        let mut sim = AccelSimulator::new(
            &man,
            &w,
            AccelConfig {
                n_pe,
                batch: man.batch_infer,
                ..Default::default()
            },
            Scheme::BatchLevel,
        )
        .unwrap();
        sim.infer_batch(&ds.signals).unwrap()
    };
    let a = run(4);
    let b = run(32);
    for p in Param::ALL {
        assert_eq!(a.samples[p.index()], b.samples[p.index()]);
    }
}

#[test]
fn mask_zero_skipping_saves_storage_and_ops_system_level() {
    let (man, w) = setup();
    let sim = AccelSimulator::new(
        &man,
        &w,
        AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        },
        Scheme::BatchLevel,
    )
    .unwrap();
    for store in sim.weight_stores() {
        assert!(store.total_skipped_words() < store.total_dense_words());
        let r = store.savings_ratio();
        assert!(r > 0.2, "savings {r} too small for scale-2 masks");
    }
}

#[test]
fn batch_level_scheme_cuts_energy_not_accuracy() {
    let (man, w) = setup();
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 400);
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut b = AccelSimulator::new(&man, &w, cfg, Scheme::BatchLevel).unwrap();
    let mut s = AccelSimulator::new(&man, &w, cfg, Scheme::SamplingLevel).unwrap();
    let (ob, st_b) = b.infer_batch_stats(&ds.signals).unwrap();
    let (os, st_s) = s.infer_batch_stats(&ds.signals).unwrap();
    // identical results
    for p in Param::ALL {
        assert_eq!(ob.samples[p.index()], os.samples[p.index()]);
    }
    // energy: batch-level strictly cheaper via the power model
    let u = uivim::accel::resource::usage(&cfg, man.nb, man.n_samples, &b.weight_stores());
    let pb = uivim::accel::power::estimate(&cfg, &u, &st_b, uivim::accel::MaskSampler::Offline);
    let ps = uivim::accel::power::estimate(&cfg, &u, &st_s, uivim::accel::MaskSampler::Offline);
    assert!(
        pb.energy_j < ps.energy_j,
        "batch-level must cost less energy: {} vs {}",
        pb.energy_j,
        ps.energy_j
    );
}

#[test]
fn fit_baselines_vs_network_on_clean_data() {
    // Classical fits are accurate on clean voxels — the network's value
    // is speed and uncertainty, not noiseless accuracy (paper §II-B).
    let (man, _) = setup();
    let ds = synth_dataset(32, &man.bvalues, 1e6, 500); // ~noiseless
    for i in 0..8 {
        let sig: Vec<f64> = ds.voxel(i).iter().map(|&v| v as f64).collect();
        let fit = uivim::fit::levenberg_marquardt(&man.bvalues, &sig);
        let t = &ds.truth[i];
        assert!((fit.params.d - t.d).abs() < 3e-4, "voxel {i}: {:?} vs {:?}", fit.params, t);
        assert!((fit.params.f - t.f).abs() < 0.12);
    }
}
