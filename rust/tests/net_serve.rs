//! Loopback end-to-end tests for the TCP front door (ISSUE #9): real
//! sockets against a fixture coordinator.  Framed replies must be
//! bit-identical to the direct `submit_leased` path, steady-state
//! ingest must allocate nothing (lease high-water flat across 100+
//! framed requests), concurrent clients route correctly, the
//! connection cap answers with an explicit `OVERLOADED` goodbye, and
//! shutdown under open connections answers everything already admitted.
//!
//! Runs on the deterministic in-tree fixture, so nothing here skips when
//! the Python-exported artifacts are absent.

use std::sync::Arc;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, NetClient, NetConfig, NetServer};
use uivim::infer::registry::{factory, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Manifest;
use uivim::testing::fixture;
use uivim::util::frame::Status;

fn start(batch: usize, capacity: usize, shards: usize) -> (Arc<Coordinator>, Manifest) {
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.queue_capacity = capacity;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let opts = EngineOpts {
        batch: Some(batch),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        factory("native", man.clone(), w, opts).expect("known engine"),
    )
    .expect("coordinator start");
    (Arc::new(coord), man)
}

fn serve(coord: &Arc<Coordinator>, cfg: NetConfig) -> (NetServer, String) {
    let server =
        NetServer::start(Arc::clone(coord), "127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = server.addr().to_string();
    (server, addr)
}

/// The tentpole contract: a request that travels the wire — frame
/// encode, socket, zero-copy decode into a lease, f64 report payload
/// back — produces the same bits as handing the coordinator the lease
/// directly.  Same coordinator, same signals, compared voxel by voxel.
#[test]
fn framed_replies_are_bit_identical_to_direct_submission() {
    let (coord, man) = start(8, 10_000, 2);
    let (server, addr) = serve(&coord, NetConfig::default());
    let n = 40usize;
    let ds = synth_dataset(n, &man.bvalues, 20.0, 303);

    // Direct path first: lease + submit_leased, no sockets.
    let direct: Vec<_> = (0..n)
        .map(|i| {
            let mut lease = coord.lease();
            lease.copy_from(ds.voxel(i));
            let rx = coord.submit_leased(i as u64, lease).expect("capacity sized");
            rx.recv_timeout(Duration::from_secs(30)).expect("direct response").report
        })
        .collect();

    // Framed path: the same voxels over loopback TCP.
    let mut client = NetClient::connect(&addr).expect("connect");
    for (i, want) in direct.iter().enumerate() {
        let id = 1_000 + i as u64;
        let reply = client.request(id, 0, ds.voxel(i)).expect("framed request");
        assert_eq!(reply.id, id, "reply routed to the wrong request");
        assert_eq!(reply.status, Status::Ok);
        let got = reply.report.expect("OK reply carries a report");
        for p in Param::ALL {
            let (g, w) = (got.get(p), want.get(p));
            assert_eq!(g.mean.to_bits(), w.mean.to_bits(), "voxel {i} {p:?} mean");
            assert_eq!(g.std.to_bits(), w.std.to_bits(), "voxel {i} {p:?} std");
            assert_eq!(
                g.relative.to_bits(),
                w.relative.to_bits(),
                "voxel {i} {p:?} relative"
            );
        }
        assert_eq!(got.confident, want.confident, "voxel {i} confidence flag");
    }
    server.shutdown();
}

/// Zero-allocation steady state: after warm-up, 120 more framed
/// requests must not grow the lease slab by a single buffer — the
/// socket path decodes straight into recycled leases.
#[test]
fn lease_high_water_stays_flat_across_framed_requests() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let ds = synth_dataset(8, &man.bvalues, 20.0, 71);
    let mut client = NetClient::connect(&addr).expect("connect");
    for i in 0..16u64 {
        let r = client.request(i, 0, ds.voxel((i % 8) as usize)).expect("warm-up");
        assert_eq!(r.status, Status::Ok);
    }
    let warm = coord.lease_high_water();
    assert!(warm >= 1, "warm-up must have taken at least one lease");
    for i in 0..120u64 {
        let r = client
            .request(100 + i, 0, ds.voxel((i % 8) as usize))
            .expect("steady-state request");
        assert_eq!(r.status, Status::Ok);
    }
    assert_eq!(
        coord.lease_high_water(),
        warm,
        "framed ingest allocated fresh lease buffers in steady state"
    );
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.net_frames, 136, "every frame counted exactly once");
    assert_eq!(snap.net_bad_frames, 0);
    assert_eq!(snap.net_shed, 0);
    server.shutdown();
}

/// Four concurrent clients, each its own connection and id space: every
/// reply routes to the request that asked for it, with plausible
/// estimates, and the coordinator's counters balance.
#[test]
fn concurrent_clients_are_routed_correctly() {
    let (coord, man) = start(16, 100_000, 2);
    let (server, addr) = serve(&coord, NetConfig::default());
    let n_clients = 4usize;
    let per = 50usize;
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let addr = addr.clone();
            let man = man.clone();
            s.spawn(move || {
                let ds = synth_dataset(per, &man.bvalues, 20.0, 500 + c as u64);
                let mut client = NetClient::connect(&addr).expect("connect");
                for i in 0..per {
                    let id = (c * per + i) as u64;
                    let reply = client.request(id, 0, ds.voxel(i)).expect("request");
                    assert_eq!(reply.id, id, "cross-client reply routing broke");
                    assert_eq!(reply.status, Status::Ok);
                    let d = reply.report.expect("report").get(Param::D);
                    assert!(d.mean >= 0.0 && d.mean <= 0.005);
                    assert!(d.std.is_finite());
                }
            });
        }
    });
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.responses, (n_clients * per) as u64);
    assert_eq!(snap.net_frames, (n_clients * per) as u64);
    assert_eq!(snap.net_connections, n_clients as u64);
    server.shutdown();
}

/// Beyond `max_conns` live connections the acceptor answers with one
/// explicit `OVERLOADED` goodbye frame and closes — never a silent
/// stall; the admitted connection keeps working throughout.
#[test]
fn connection_cap_rejects_with_explicit_overloaded() {
    let (coord, man) = start(8, 10_000, 1);
    let cfg = NetConfig {
        max_conns: 1,
        ..Default::default()
    };
    let (server, addr) = serve(&coord, cfg);
    let ds = synth_dataset(2, &man.bvalues, 20.0, 13);
    let mut first = NetClient::connect(&addr).expect("connect");
    // A full round trip guarantees the first connection is registered.
    let r = first.request(1, 0, ds.voxel(0)).expect("admitted client");
    assert_eq!(r.status, Status::Ok);

    let mut second = NetClient::connect(&addr).expect("TCP connect still succeeds");
    let goodbye = second.recv().expect("goodbye frame");
    assert_eq!(goodbye.status, Status::Overloaded, "explicit rejection");
    assert!(goodbye.report.is_none());
    assert!(
        second.recv().is_err(),
        "rejected connection must be closed after the goodbye"
    );
    // The admitted connection is unaffected.
    let r = first.request(2, 0, ds.voxel(1)).expect("still served");
    assert_eq!(r.status, Status::Ok);
    server.shutdown();
}

/// Shutdown with a connection open: everything the server admitted is
/// answered (`OK`) or explicitly rejected (`SHUTDOWN`/`EXPIRED`) before
/// the threads join — and afterwards the client sees a clean close, not
/// a hang.
#[test]
fn shutdown_with_open_connections_answers_everything() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let ds = synth_dataset(5, &man.bvalues, 20.0, 29);
    let mut client =
        NetClient::connect_with_timeout(&addr, Duration::from_secs(10)).expect("connect");
    for i in 0..5u64 {
        client.send(i, 0, ds.voxel(i as usize)).expect("send");
    }
    // Let the connection thread ingest and the coordinator serve.
    std::thread::sleep(Duration::from_millis(300));
    server.shutdown(); // joins every connection thread

    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..5 {
        let reply = client.recv().expect("every admitted request is answered");
        assert!(
            matches!(reply.status, Status::Ok | Status::Shutdown | Status::Expired),
            "unexpected terminal status {:?}",
            reply.status
        );
        assert!(seen.insert(reply.id), "request {} answered twice", reply.id);
    }
    assert_eq!(seen, (0..5u64).collect());
    // The socket is closed afterwards — a late request cannot hang.
    let _ = client.send(99, 0, ds.voxel(0));
    assert!(client.recv().is_err(), "server gone: clean close, not a stall");
    drop(coord);
}
