//! Adversarial-input tests for the TCP front door (ISSUE #9): every
//! malformed, hostile or merely unlucky byte stream must produce a
//! *typed* rejection (or a clean close) — never a panic, an over-read,
//! a stall, or a leaked lease.  The coordinator behind the server must
//! stay fully serviceable after every attack.
//!
//! Runs on the deterministic in-tree fixture, so nothing here skips when
//! the Python-exported artifacts are absent.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, NetClient, NetConfig, NetServer};
use uivim::infer::registry::{factory, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::model::Manifest;
use uivim::testing::fixture;
use uivim::util::frame::{encode_request, Status, HEADER_LEN};
use uivim::util::rng::Pcg32;

fn start(batch: usize, capacity: usize, shards: usize) -> (Arc<Coordinator>, Manifest) {
    let (man, w) = fixture::tiny_fixture();
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.queue_capacity = capacity;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let opts = EngineOpts {
        batch: Some(batch),
        ..Default::default()
    };
    let coord = Coordinator::start(
        cfg,
        factory("native", man.clone(), w, opts).expect("known engine"),
    )
    .expect("coordinator start");
    (Arc::new(coord), man)
}

fn serve(coord: &Arc<Coordinator>, cfg: NetConfig) -> (NetServer, String) {
    let server =
        NetServer::start(Arc::clone(coord), "127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = server.addr().to_string();
    (server, addr)
}

/// A short-timeout client for reads where a rejection (or close) is the
/// expected outcome.
fn attack_client(addr: &str) -> NetClient {
    NetClient::connect_with_timeout(addr, Duration::from_secs(5)).expect("connect")
}

/// One well-formed request proving the server still serves after an
/// attack.
fn assert_still_serves(addr: &str, man: &Manifest, id: u64) {
    let ds = synth_dataset(1, &man.bvalues, 20.0, id);
    let mut client = attack_client(addr);
    let reply = client.request(id, 0, ds.voxel(0)).expect("healthy request");
    assert_eq!(reply.status, Status::Ok, "server unhealthy after attack");
    assert!(reply.report.is_some());
}

/// A truncated frame followed by a hard disconnect: the server drops
/// the connection without panicking and keeps serving others.
#[test]
fn truncated_frame_then_disconnect_is_harmless() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    {
        let mut half = attack_client(&addr);
        let mut frame = Vec::new();
        encode_request(&mut frame, 7, 0, &vec![0.5f32; man.nb]);
        half.send_raw(&frame[..HEADER_LEN - 3]).expect("partial header");
        // dropped here: the server sees a half-frame then EOF
    }
    assert_still_serves(&addr, &man, 1);
    assert_eq!(coord.metrics().snapshot().net_frames, 1, "half-frame never parsed");
    server.shutdown();
}

/// A header declaring an absurd payload length (the classic
/// length-prefix attack): rejected from the header alone — the server
/// never waits for, nor allocates, the declared payload — with a typed
/// `BAD_REQUEST` before the connection closes.
#[test]
fn declared_length_overflow_is_rejected_before_payload() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let lease_before = coord.lease_high_water();

    let mut client = attack_client(&addr);
    let mut frame = Vec::new();
    encode_request(&mut frame, 9, 0, &vec![0.5f32; man.nb]);
    frame[24..28].copy_from_slice(&u32::MAX.to_le_bytes()); // n_values = 4 Gi
    client.send_raw(&frame[..HEADER_LEN]).expect("hostile header");
    let reply = client.recv().expect("typed rejection");
    assert_eq!(reply.status, Status::BadRequest);
    assert!(client.recv().is_err(), "desynced stream must be closed");

    assert_eq!(
        coord.lease_high_water(),
        lease_before,
        "oversize rejection must not touch the lease slab"
    );
    assert!(coord.metrics().snapshot().net_bad_frames >= 1);
    assert_still_serves(&addr, &man, 2);
    server.shutdown();
}

/// An under-declared length (fewer values than the protocol width) is a
/// *recoverable* typed rejection: the frame is well-formed, just wrong,
/// so the connection survives and the next request is served.
#[test]
fn wrong_width_is_rejected_but_connection_survives() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let ds = synth_dataset(1, &man.bvalues, 20.0, 41);

    let mut client = attack_client(&addr);
    let mut frame = Vec::new();
    encode_request(&mut frame, 11, 0, &vec![0.5f32; man.nb - 1]);
    client.send_raw(&frame).expect("narrow frame");
    let reply = client.recv().expect("typed rejection");
    assert_eq!(reply.id, 11, "rejection echoes the offending id");
    assert_eq!(reply.status, Status::BadRequest);
    // Same connection, correct width: served.
    let reply = client.request(12, 0, ds.voxel(0)).expect("recovered");
    assert_eq!(reply.status, Status::Ok);
    server.shutdown();
}

/// Bad magic and bad version each draw a typed rejection and a close —
/// the stream cannot be trusted past the first corrupt header.
#[test]
fn bad_magic_and_bad_version_get_typed_rejections() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let good = {
        let mut f = Vec::new();
        encode_request(&mut f, 21, 0, &vec![0.5f32; man.nb]);
        f
    };
    for (corrupt, what) in [(0usize..4, "magic"), (4..6, "version")] {
        let mut frame = good.clone();
        for b in &mut frame[corrupt] {
            *b = 0xFF;
        }
        let mut client = attack_client(&addr);
        client.send_raw(&frame).expect("corrupt frame");
        let reply = client.recv().unwrap_or_else(|e| panic!("typed {what} rejection: {e}"));
        assert_eq!(reply.status, Status::BadRequest, "{what}");
        assert!(client.recv().is_err(), "{what}: connection must close");
    }
    assert_eq!(coord.metrics().snapshot().net_bad_frames, 2);
    assert_still_serves(&addr, &man, 3);
    server.shutdown();
}

/// NaN / Inf payload floats are rejected with `BAD_REQUEST`, the lease
/// taken for the zero-copy decode is reclaimed (high-water flat), and
/// the connection survives.
#[test]
fn nonfinite_payload_is_rejected_and_lease_reclaimed() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let ds = synth_dataset(1, &man.bvalues, 20.0, 43);

    let mut client = attack_client(&addr);
    // Warm the slab with one good request so the high-water is settled.
    let reply = client.request(30, 0, ds.voxel(0)).expect("warm-up");
    assert_eq!(reply.status, Status::Ok);
    let warm = coord.lease_high_water();

    for (i, bad) in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY]
        .into_iter()
        .enumerate()
    {
        let mut signals = vec![0.5f32; man.nb];
        signals[i % man.nb] = bad;
        let reply = client.request(31 + i as u64, 0, &signals).expect("typed rejection");
        assert_eq!(reply.id, 31 + i as u64);
        assert_eq!(reply.status, Status::BadRequest, "non-finite {bad} admitted");
    }
    assert_eq!(
        coord.lease_high_water(),
        warm,
        "rejected payloads leaked lease buffers"
    );
    // The connection is still good.
    let reply = client.request(35, 0, ds.voxel(0)).expect("recovered");
    assert_eq!(reply.status, Status::Ok);
    assert_eq!(coord.metrics().snapshot().net_bad_frames, 3);
    server.shutdown();
}

/// Slow-loris: a client that sends half a header and then goes quiet is
/// disconnected once `idle_timeout` passes — it cannot pin a connection
/// slot forever.
#[test]
fn slow_loris_partial_frame_is_disconnected() {
    let (coord, man) = start(8, 10_000, 1);
    let cfg = NetConfig {
        idle_timeout: Duration::from_millis(100),
        ..Default::default()
    };
    let (server, addr) = serve(&coord, cfg);

    let mut loris = attack_client(&addr);
    loris.send_raw(&[0x55; 10]).expect("drip-feed"); // not even a header
    let err = loris.recv().expect_err("idle half-frame must be disconnected");
    assert!(
        err.to_string().contains("closed") || err.to_string().contains("reply"),
        "unexpected failure mode: {err}"
    );
    assert_still_serves(&addr, &man, 4);
    server.shutdown();
}

/// Seeded random-bytes property loop: whatever bytes arrive, the server
/// never panics, never over-reads, never leaks a lease, and is still
/// fully serviceable afterwards.  The seed makes any failure replay.
#[test]
fn random_bytes_never_panic_or_leak() {
    let (coord, man) = start(8, 10_000, 1);
    let (server, addr) = serve(&coord, NetConfig::default());
    let ds = synth_dataset(1, &man.bvalues, 20.0, 47);

    // Settle the slab's high-water with a legitimate request first.
    {
        let mut c = attack_client(&addr);
        assert_eq!(c.request(50, 0, ds.voxel(0)).expect("warm").status, Status::Ok);
    }
    let warm = coord.lease_high_water();

    let mut rng = Pcg32::new(0xF8A3_0009);
    for round in 0..24 {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream
            .set_write_timeout(Some(Duration::from_secs(5)))
            .expect("write timeout");
        let len = 1 + rng.below(200) as usize;
        let mut bytes = vec![0u8; len];
        for b in bytes.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        if rng.below(3) == 0 {
            // a plausible prefix makes the parser walk further
            bytes[..4.min(len)].copy_from_slice(&b"UIVM"[..4.min(len)]);
        }
        // The server may close mid-write (typed rejection + close) —
        // a write error is an acceptable outcome, a hang is not.
        let _ = stream.write_all(&bytes);
        drop(stream);
        if round % 6 == 5 {
            // periodically prove the server is still alive and leak-free
            assert_still_serves(&addr, &man, 60 + round as u64);
            assert_eq!(
                coord.lease_high_water(),
                warm,
                "garbage round {round} leaked a lease"
            );
        }
    }
    assert_still_serves(&addr, &man, 99);
    assert_eq!(coord.lease_high_water(), warm, "garbage storm leaked leases");
    server.shutdown();
}
