//! Bench: paper Table II — latency / power / energy per batch across
//! platforms (CPU native, CPU PJRT, derived GPU, simulated FPGA).
//!
//! Run: `cargo bench --bench table2_platforms`
//! Env: `UIVIM_BENCH_FAST=1` for a quick pass,
//!      `UIVIM_VARIANT=tiny|paper` (default paper).

use uivim::bench::config_from_env;
use uivim::experiments::{load_manifest, tables};
use uivim::model::Weights;
use uivim::runtime::Runtime;

fn main() {
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "paper".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    // Table II benches the PJRT engine; skip cleanly on the stub build.
    if let Err(e) = Runtime::cpu() {
        eprintln!("skipping: Table II benches the PJRT engine ({e})");
        return;
    }
    let w = Weights::load_init(&man).expect("init weights");
    let t = tables::table2(&man, &w, &config_from_env()).expect("table2");
    println!(
        "\n== Table II ({} variant, batch {} x {} b-values) ==\n",
        man.variant, man.batch_infer, man.nb
    );
    println!("{}", tables::render_table2(&t));
}
