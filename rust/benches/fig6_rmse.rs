//! Bench: paper Fig. 6 — RMSE of predicted IVIM parameters vs evaluation
//! SNR {5, 15, 20, 30, 50}.
//!
//! Trains (or reuses cached) weights, then evaluates the SNR grid and
//! prints the table + ASCII plot.  Env: `UIVIM_VARIANT`,
//! `UIVIM_BENCH_FAST=1` (fewer voxels / steps).

use uivim::experiments::{fig67, load_manifest, resolve_weights};
use uivim::runtime::Runtime;

fn main() {
    let fast = std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "tiny".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let rt = Runtime::cpu().ok();
    let steps = if rt.is_some() {
        if fast { 150 } else { 500 }
    } else {
        eprintln!("PJRT unavailable: evaluating artifact init weights (no training)");
        0
    };
    let w = resolve_weights(&man, rt.as_ref(), None, steps, 20.0).expect("weights");
    let cfg = fig67::SweepConfig {
        n_voxels: if fast { 500 } else { 2000 },
        engine: "native".into(),
        ..Default::default()
    };
    let rows = fig67::snr_sweep(&man, &w, &cfg).expect("sweep");
    println!(
        "\n== Fig. 6 ({} variant, {} voxels/SNR, {} train steps) ==\n",
        man.variant, cfg.n_voxels, steps
    );
    println!("{}", fig67::render_fig6(&rows));
}
