//! Bench: §II-C ablation — Masksembles vs MC-Dropout vs Deep Ensembles:
//! uncertainty quality vs the hardware costs the co-design exploits.

use uivim::experiments::{ablation, load_manifest, resolve_weights};
use uivim::runtime::Runtime;

fn main() {
    let fast = std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "tiny".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let rt = Runtime::cpu().ok();
    let steps = if rt.is_some() {
        if fast { 150 } else { 400 }
    } else {
        eprintln!("PJRT unavailable: running the ablation on init weights");
        0
    };
    let w = resolve_weights(&man, rt.as_ref(), None, steps, 20.0).expect("weights");
    let rows = ablation::ablation(&man, &w).expect("ablation");
    println!(
        "\n== Uncertainty-method ablation ({} variant, {} train steps) ==\n",
        man.variant, steps
    );
    println!("{}", ablation::render(&rows));
    println!(
        "The co-design argument: Masksembles keeps Deep-Ensemble-style determinism\n\
         (exact repeatability, no runtime sampler) at MC-Dropout-style memory cost —\n\
         which is precisely what enables mask-zero skipping and batch-level loading."
    );
}
