//! Bench: paper Fig. 7 — relative uncertainty (std/mean) of predicted
//! parameters vs evaluation SNR, plus calibration correlation.
//!
//! Env: `UIVIM_VARIANT`, `UIVIM_BENCH_FAST=1`.

use uivim::experiments::{fig67, load_manifest, resolve_weights};
use uivim::runtime::Runtime;

fn main() {
    let fast = std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "tiny".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let rt = Runtime::cpu().ok();
    let steps = if rt.is_some() {
        if fast { 150 } else { 500 }
    } else {
        eprintln!("PJRT unavailable: evaluating artifact init weights (no training)");
        0
    };
    let w = resolve_weights(&man, rt.as_ref(), None, steps, 20.0).expect("weights");
    let cfg = fig67::SweepConfig {
        n_voxels: if fast { 500 } else { 2000 },
        engine: "native".into(),
        ..Default::default()
    };
    let rows = fig67::snr_sweep(&man, &w, &cfg).expect("sweep");
    println!(
        "\n== Fig. 7 ({} variant, {} voxels/SNR) ==\n",
        man.variant, cfg.n_voxels
    );
    println!("{}", fig67::render_fig7(&rows));
}
