//! Bench: micro-benchmarks of the hot paths — fixed-point ops, PU dot
//! products, the native hidden block, mask generation and the synthetic
//! data generator.  These feed the EXPERIMENTS.md §Perf iteration log.

use uivim::accel::fixed::{quantize_slice, Fx};
use uivim::accel::pu::{pu_dot, PuConfig};
use uivim::accel::{AccelConfig, AccelSimulator, Scheme};
use uivim::bench::{
    bench, black_box, config_from_env, print_results, write_bench_json, BenchRecord,
};
use uivim::experiments::load_manifest;
use uivim::infer::native::{masked_linear_reference, BlockedMaskedLinear, NativeEngine};
use uivim::infer::registry::{build, EngineOpts};
use uivim::infer::InferOutput;
use uivim::ivim::synth::synth_dataset;
use uivim::bayes::{pipeline, McDropout};
use uivim::infer::Engine;
use uivim::masks::{self, MaskPlan};
use uivim::model::Weights;
use uivim::testing::fixture;
use uivim::util::rng::Pcg32;
use uivim::util::workers::WorkerPool;

/// Blocked vs scalar masked-linear at the paper's operating point
/// (nb=104, batch 64, N=4 masks at p=0.5 density): the seed scalar path
/// runs every sample's kept outputs per voxel; the blocked path packs
/// the union weight block once and shares it across samples.  The
/// acceptance bar for ISSUE #1 is >= 2x throughput here.
fn masked_linear_blocked_vs_scalar(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> f64 {
    let nb = 104usize;
    let batch = 64usize;
    let n_samples = 4usize;
    let p_density = 2.0; // Masksembles scale 2.0 == Bernoulli keep rate 0.5
    let mask = masks::for_width(nb, n_samples, p_density, 33).unwrap();

    let mut rng = Pcg32::new(21);
    let w_t: Vec<f32> = (0..nb * nb)
        .map(|_| rng.uniform(-0.4, 0.4) as f32)
        .collect();
    let b: Vec<f32> = (0..nb).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let scale: Vec<f32> = (0..nb).map(|_| rng.uniform(0.8, 1.2) as f32).collect();
    let shift: Vec<f32> = (0..nb).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let x: Vec<f32> = (0..batch * nb)
        .map(|_| rng.uniform(0.0, 1.0) as f32)
        .collect();
    let kept: Vec<Vec<usize>> = (0..n_samples).map(|s| mask.kept_indices(s)).collect();

    let mut out_scalar = vec![0.0f32; batch * nb];
    let r_scalar = bench("masked_linear_scalar_p0.5_x4", cfg, || {
        for ks in &kept {
            masked_linear_reference(
                nb,
                batch,
                &x,
                &w_t,
                &b,
                &scale,
                &shift,
                ks,
                &mut out_scalar,
            );
            black_box(&out_scalar);
        }
    });

    let layer = BlockedMaskedLinear::new(nb, &w_t, &b, &scale, &shift, &mask);
    let mut act = vec![0.0f32; layer.union_len() * batch];
    let mut out_blocked = vec![0.0f32; batch * nb];
    let r_blocked = bench("masked_linear_blocked_p0.5_x4", cfg, || {
        layer.forward_union(batch, &x, &mut act);
        for s in 0..n_samples {
            layer.scatter_sample(s, batch, &act, &mut out_blocked);
            black_box(&out_blocked);
        }
    });

    // Cross-check before trusting the timing: both paths must agree
    // bit-for-bit on the last sample computed above.
    masked_linear_reference(
        nb,
        batch,
        &x,
        &w_t,
        &b,
        &scale,
        &shift,
        &kept[n_samples - 1],
        &mut out_scalar,
    );
    assert_eq!(out_scalar, out_blocked, "blocked path diverged from scalar");

    let speedup = r_scalar.mean_s / r_blocked.mean_s;
    println!(
        "masked-linear blocked speedup vs seed scalar path @ p=0.5: {speedup:.2}x \
         ({:.2} us -> {:.2} us per 4-sample layer)",
        r_scalar.mean_us(),
        r_blocked.mean_us()
    );
    results.push(r_scalar);
    results.push(r_blocked);
    speedup
}

/// Mask lifecycle at paper scale (nb=104): the per-redraw cost of the
/// in-place `resample + swap_masks` hot path vs tearing the engine down
/// and rebuilding it with the new masks baked in (the pre-refactor
/// `McDropout` sampler cost).  Both include the Bernoulli redraw.
fn mask_swap_vs_fresh_rebuild(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> f64 {
    let (man, w) = fixture::paper_fixture();
    let mut rng = Pcg32::new(55);
    let mut plan = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);

    let mut eng = NativeEngine::with_batch(&man, &w, man.batch_infer).unwrap();
    let r_swap = bench("mask_swap_paper", cfg, || {
        plan.resample(&mut rng);
        eng.swap_masks(&plan).unwrap();
        black_box(&eng);
    });

    let r_fresh = bench("mask_fresh_rebuild_paper", cfg, || {
        plan.resample(&mut rng);
        let mut man2 = man.clone();
        plan.apply_to_manifest(&mut man2);
        let fresh = NativeEngine::with_batch(&man2, &w, man.batch_infer).unwrap();
        black_box(&fresh);
    });

    let speedup = r_fresh.mean_s / r_swap.mean_s;
    println!(
        "mask swap vs fresh engine rebuild @ nb=104: {speedup:.2}x \
         ({:.2} us -> {:.2} us per mask redraw)",
        r_fresh.mean_us(),
        r_swap.mean_us()
    );
    results.push(r_fresh);
    results.push(r_swap);
    speedup
}

/// Simulator-side mask lifecycle at paper scale (the ISSUE #5 tentpole):
/// `resample + AccelSimulator::swap_masks` (in-place kept-column
/// re-selection over the once-quantised weight block) vs a full datapath
/// re-instantiation with the masks baked into the manifest.
fn accel_mask_swap_vs_rebuild(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> f64 {
    let (man, w) = fixture::paper_fixture();
    let mut rng = Pcg32::new(56);
    let mut plan = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);
    let acfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut sim = AccelSimulator::new(&man, &w, acfg, Scheme::BatchLevel).unwrap();
    let r_swap = bench("accel_mask_swap_paper", cfg, || {
        plan.resample(&mut rng);
        sim.swap_masks(&plan).unwrap();
        black_box(&sim);
    });

    let r_fresh = bench("accel_datapath_rebuild_paper", cfg, || {
        plan.resample(&mut rng);
        let mut man2 = man.clone();
        plan.apply_to_manifest(&mut man2);
        let fresh = AccelSimulator::new(&man2, &w, acfg, Scheme::BatchLevel).unwrap();
        black_box(&fresh);
    });

    let speedup = r_fresh.mean_s / r_swap.mean_s;
    println!(
        "accel mask swap vs datapath re-instantiation @ nb=104: {speedup:.2}x \
         ({:.2} us -> {:.2} us per mask redraw)",
        r_fresh.mean_us(),
        r_swap.mean_us()
    );
    results.push(r_fresh);
    results.push(r_swap);
    speedup
}

/// f32 dot-kernel dispatch vs the scalar oracle at paper width (the SIMD
/// tentpole): `kernels::dot_one(Exact, ..)` — the SSE2 kernel under the
/// `simd` feature, the scalar chain otherwise — against `dot_one_scalar`
/// called directly.  64 dots per iteration so the timer resolves the
/// sub-microsecond kernel.  Bit-equality is asserted before timing:
/// Exact mode's contract is that dispatch never changes a single bit.
fn dot_one_dispatch_vs_scalar(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> f64 {
    use uivim::infer::kernels::{backend, dot_one, dot_one_scalar, DotMode};
    let nb = 104usize;
    let mut rng = Pcg32::new(77);
    let x: Vec<f32> = (0..nb).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let ws: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..nb).map(|_| rng.uniform(-0.4, 0.4) as f32).collect())
        .collect();

    for w in &ws {
        assert_eq!(
            dot_one(DotMode::Exact, nb, &x, w).to_bits(),
            dot_one_scalar(nb, &x, w).to_bits(),
            "Exact dispatch diverged from the scalar oracle"
        );
    }

    let r_dispatch = bench("dot_one_dispatch_104_x64", cfg, || {
        let mut s = 0.0f32;
        for w in &ws {
            s += dot_one(DotMode::Exact, nb, &x, w);
        }
        black_box(s);
    });
    let r_scalar = bench("dot_one_scalar_104_x64", cfg, || {
        let mut s = 0.0f32;
        for w in &ws {
            s += dot_one_scalar(nb, &x, w);
        }
        black_box(s);
    });

    let speedup = r_scalar.mean_s / r_dispatch.mean_s;
    println!(
        "f32 dot dispatch ({:?}) vs scalar oracle @ nb=104: {speedup:.2}x \
         ({:.2} us -> {:.2} us per 64 dots)",
        backend(DotMode::Exact),
        r_scalar.mean_us(),
        r_dispatch.mean_us()
    );
    results.push(r_scalar);
    results.push(r_dispatch);
    speedup
}

/// Fixed-point chunk-MAC dispatch vs the scalar adder tree at paper
/// width: `Pu::dot_acc` (the AVX2 kernel under the `simd` feature on a
/// capable CPU, the scalar tree otherwise) against `pu_dot_acc_into` on
/// a reused scratch.  Both sides are allocation-free in steady state,
/// so this isolates the SIMD gain from the alloc-lift bugfix (which
/// `pu_dot_104` vs these cases captures).  Integer accumulation reorders
/// exactly, so bit-equality is asserted before timing.
fn fx_dot_dispatch_vs_scalar(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> f64 {
    use uivim::accel::pu::{pu_dot_acc_into, Pu};
    let n = 104usize;
    let mut rng = Pcg32::new(78);
    let x: Vec<Fx> = (0..n)
        .map(|_| Fx::from_f32(rng.uniform(-2.0, 2.0) as f32))
        .collect();
    let ws: Vec<Vec<Fx>> = (0..64)
        .map(|_| {
            (0..n)
                .map(|_| Fx::from_f32(rng.uniform(-0.5, 0.5) as f32))
                .collect()
        })
        .collect();

    let mut pu = Pu::new(PuConfig::default());
    let pcfg = *pu.config();
    let mut scratch = vec![0i64; pcfg.lanes];
    for w in &ws {
        let got = pu.dot_acc(&x, w);
        let want = pu_dot_acc_into(&pcfg, &mut scratch, &x, w);
        assert_eq!(got, want, "fixed-point dispatch diverged from the scalar tree");
    }

    let r_dispatch = bench("fx_dot_acc_dispatch_104_x64", cfg, || {
        let mut s = 0i64;
        for w in &ws {
            s = s.wrapping_add(pu.dot_acc(&x, w));
        }
        black_box(s);
    });
    let r_scalar = bench("fx_dot_acc_scalar_104_x64", cfg, || {
        let mut s = 0i64;
        for w in &ws {
            s = s.wrapping_add(pu_dot_acc_into(&pcfg, &mut scratch, &x, w));
        }
        black_box(s);
    });

    let speedup = r_scalar.mean_s / r_dispatch.mean_s;
    println!(
        "fixed-point chunk-MAC dispatch ({}) vs scalar tree @ n=104: {speedup:.2}x \
         ({:.2} us -> {:.2} us per 64 dots)",
        Pu::new(pcfg).backend(),
        r_scalar.mean_us(),
        r_dispatch.mean_us()
    );
    results.push(r_scalar);
    results.push(r_dispatch);
    speedup
}

/// Full MC pass at paper scale, serial oracle vs the pipelined head
/// (the ISSUE #8 tentpole): the serial head pays `resample + swap`
/// on the critical path every pass; the pipelined head overlaps the
/// redraw with the previous pass's execute and pays only the swap.
/// Bit-equality is asserted before timing — the overlap is a pure
/// scheduling change.  Returns (speedup, overlap_hides_swap_fraction):
/// the fraction of the serial sampler cost the overlap actually hid,
/// clamped to [0, 1].
fn mc_pass_pipelined_vs_serial(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) -> (f64, f64) {
    let (man, w) = fixture::paper_fixture();
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 91);

    // Cross-check before trusting the timing.
    let mut serial = McDropout::with_batch(&man, &w, man.batch_infer, 91).unwrap();
    let mut piped = pipeline::mc_dropout(&man, &w, man.batch_infer, 91, 1).unwrap();
    let mut a = InferOutput::new(serial.n_samples(), serial.batch_size());
    let mut b = InferOutput::new(piped.n_samples(), piped.batch_size());
    for pass in 0..4 {
        serial.execute_into(&ds.signals, &mut a).unwrap();
        piped.execute_into(&ds.signals, &mut b).unwrap();
        assert_eq!(a.samples, b.samples, "pass {pass}: pipelined diverged from serial");
    }

    let r_serial = bench("mc_pass_serial_paper", cfg, || {
        serial.execute_into(&ds.signals, &mut a).unwrap();
        black_box(&a);
    });
    let r_piped = bench("mc_pass_pipelined_paper", cfg, || {
        piped.execute_into(&ds.signals, &mut b).unwrap();
        black_box(&b);
    });

    // The per-pass sampler cost the overlap is hiding: redraw + swap on
    // an otherwise idle engine.
    let mut rng = Pcg32::new(92);
    let mut plan = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);
    let mut eng = NativeEngine::with_batch(&man, &w, man.batch_infer).unwrap();
    let r_sampler = bench("mc_sampler_serial_paper", cfg, || {
        plan.resample(&mut rng);
        eng.swap_masks(&plan).unwrap();
        black_box(&eng);
    });

    let speedup = r_serial.mean_s / r_piped.mean_s;
    let hidden = ((r_serial.mean_s - r_piped.mean_s) / r_sampler.mean_s).clamp(0.0, 1.0);
    println!(
        "MC pass pipelined vs serial @ paper scale: {speedup:.2}x \
         ({:.2} us -> {:.2} us per pass; sampler {:.2} us, {:.0}% hidden)",
        r_serial.mean_us(),
        r_piped.mean_us(),
        r_sampler.mean_us(),
        hidden * 100.0
    );
    results.push(r_serial);
    results.push(r_piped);
    results.push(r_sampler);
    (speedup, hidden)
}

/// Batch-tiled `forward_union` at paper shape across worker counts
/// (the ISSUE #8 worker pool): the same 4-row-blocked kernel, with the
/// voxel dimension split into per-lane tiles.  Bit-equality against the
/// single-threaded path is asserted for every thread count before any
/// timing — the tiling contract.
fn forward_union_threads(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) {
    let nb = 104usize;
    let batch = 64usize;
    let mask = masks::for_width(nb, 4, 2.0, 34).unwrap();
    let mut rng = Pcg32::new(35);
    let w_t: Vec<f32> = (0..nb * nb)
        .map(|_| rng.uniform(-0.4, 0.4) as f32)
        .collect();
    let b: Vec<f32> = (0..nb).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let scale: Vec<f32> = (0..nb).map(|_| rng.uniform(0.8, 1.2) as f32).collect();
    let shift: Vec<f32> = (0..nb).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let x: Vec<f32> = (0..batch * nb)
        .map(|_| rng.uniform(0.0, 1.0) as f32)
        .collect();
    let layer = BlockedMaskedLinear::new(nb, &w_t, &b, &scale, &shift, &mask);
    let mut act_serial = vec![0.0f32; layer.union_len() * batch];
    layer.forward_union(batch, &x, &mut act_serial);

    for threads in [1usize, 2, 4, 8] {
        let pool = WorkerPool::new(threads);
        let mut act = vec![f32::NAN; layer.union_len() * batch];
        layer.forward_union_tiled(batch, &x, &mut act, &pool);
        assert_eq!(
            act, act_serial,
            "t{threads}: tiled forward_union diverged from serial"
        );
        results.push(bench(&format!("forward_union_t{threads}"), cfg, || {
            layer.forward_union_tiled(batch, &x, &mut act, &pool);
            black_box(&act);
        }));
    }
}

/// Framed-ingest hot path (ISSUE #9): request encode into a reused
/// frame buffer, and the server-side reassembly — `feed` -> `poll` ->
/// zero-copy `decode_request_into` -> `consume` — at paper width.
/// Every buffer is reused across iterations, mirroring the per-
/// connection steady state where ingest allocates nothing.  The wire
/// roundtrip is asserted bit-exact before any timing.
fn net_frame_ingest(
    cfg: &uivim::bench::BenchConfig,
    results: &mut Vec<uivim::bench::BenchResult>,
) {
    use uivim::util::frame::{encode_request, FrameAssembler};
    let nb = 104usize;
    let mut rng = Pcg32::new(63);
    let signals: Vec<f32> = (0..nb).map(|_| rng.uniform(0.0, 1.0) as f32).collect();

    // Cross-check before trusting the timing: encode -> reassemble ->
    // decode must hand back the exact payload bits.
    let mut frame = Vec::new();
    encode_request(&mut frame, 42, 1_000, &signals);
    let mut asm = FrameAssembler::new(nb);
    let mut out = vec![0.0f32; nb];
    assert_eq!(asm.feed(&frame), frame.len());
    let header = asm.poll().expect("well-formed frame").expect("complete frame");
    assert_eq!(header.id, 42);
    assert_eq!(header.n_values, nb);
    assert!(asm.decode_request_into(&header, &mut out));
    for (got, want) in out.iter().zip(&signals) {
        assert_eq!(got.to_bits(), want.to_bits(), "wire roundtrip changed payload bits");
    }
    asm.consume(&header);

    results.push(bench("net_ingest_encode_104", cfg, || {
        encode_request(&mut frame, 42, 1_000, &signals);
        black_box(&frame);
    }));
    results.push(bench("net_ingest_parse_104", cfg, || {
        asm.feed(&frame);
        let header = asm.poll().expect("well-formed").expect("complete");
        asm.decode_request_into(&header, &mut out);
        asm.consume(&header);
        black_box(&out);
    }));
}

fn main() {
    let cfg = config_from_env();
    let mut results = Vec::new();

    let blocked_speedup = masked_linear_blocked_vs_scalar(&cfg, &mut results);
    let swap_speedup = mask_swap_vs_fresh_rebuild(&cfg, &mut results);
    let accel_swap_speedup = accel_mask_swap_vs_rebuild(&cfg, &mut results);
    let simd_speedup = dot_one_dispatch_vs_scalar(&cfg, &mut results);
    let fx_simd_speedup = fx_dot_dispatch_vs_scalar(&cfg, &mut results);
    let (mc_overlap_speedup, swap_hidden_fraction) =
        mc_pass_pipelined_vs_serial(&cfg, &mut results);
    forward_union_threads(&cfg, &mut results);
    net_frame_ingest(&cfg, &mut results);

    // fixed-point multiply-accumulate chain
    let xs: Vec<Fx> = (0..1024).map(|i| Fx::from_f32((i % 13) as f32 * 0.01)).collect();
    results.push(bench("fx_mac_1024", &cfg, || {
        let mut acc = Fx::ZERO;
        for w in xs.windows(2) {
            acc = acc.add(w[0].mul(w[1]));
        }
        black_box(acc);
    }));

    // PU dot product at paper width
    let pu = PuConfig::default();
    let w: Vec<Fx> = quantize_slice(&vec![0.01f32; 104]);
    let x: Vec<Fx> = quantize_slice(&vec![0.5f32; 104]);
    results.push(bench("pu_dot_104", &cfg, || {
        black_box(pu_dot(&pu, &x, &w, Fx::ZERO));
    }));

    // mask generation (paper width)
    let mut seed = 0u64;
    results.push(bench("masks_for_width_104", &cfg, || {
        seed += 1;
        black_box(masks::for_width(104, 4, 2.0, seed).unwrap());
    }));

    // synthetic data generator
    let bvals = uivim::ivim::bvalues_paper();
    results.push(bench("synth_1000_voxels", &cfg, || {
        black_box(synth_dataset(1000, &bvals, 20.0, 7));
    }));

    // PCG throughput
    let mut rng = Pcg32::new(3);
    results.push(bench("pcg32_normal_10k", &cfg, || {
        let mut s = 0.0;
        for _ in 0..10_000 {
            s += rng.normal();
        }
        black_box(s);
    }));

    // dispatch-structure overhead in isolation: 64 p2c pushes + 64
    // claims (local pops + steal scans) through a 16-shard deque set —
    // the pure protocol cost a batch pays on top of the engine
    let deques: uivim::coordinator::ShardDeques<usize> =
        uivim::coordinator::ShardDeques::new(16, 64);
    let mut push_rng = Pcg32::new(61);
    let mut claim_rng = Pcg32::new(62);
    results.push(bench("deque_push_claim_64x16", &cfg, || {
        for i in 0..64usize {
            deques.push_balanced(i, &mut push_rng).unwrap();
        }
        while let Some((item, _)) = deques.try_pop(0, &mut claim_rng) {
            black_box(item);
        }
    }));

    // the lease slab's take/put cycle (per-request buffer recycling)
    let slab = uivim::util::pool::VecPool::new(8);
    results.push(bench("vecpool_lease_cycle_x64", &cfg, || {
        for _ in 0..64 {
            let mut v = slab.take(104);
            v.resize(104, 1.0);
            black_box(&v);
            slab.put(v);
        }
    }));

    // classical fit baselines (paper §II-B motivation: "long fitting
    // times" of least squares vs the network's one-pass inference)
    let bt = uivim::ivim::bvalues_tiny();
    let ds1 = synth_dataset(1, &bt, 20.0, 9);
    let sig: Vec<f64> = ds1.voxel(0).iter().map(|&v| v as f64).collect();
    results.push(bench("fit_segmented_1_voxel", &cfg, || {
        black_box(uivim::fit::segmented_fit(&bt, &sig, 200.0));
    }));
    results.push(bench("fit_lm_1_voxel", &cfg, || {
        black_box(uivim::fit::levenberg_marquardt(&bt, &sig));
    }));

    // native engine batch at each variant (artifacts if present, else
    // the deterministic in-tree fixtures at the same shapes), on the
    // two-phase zero-allocation hot path (registry-constructed)
    for variant in ["tiny", "paper"] {
        let (man, w) = match load_manifest(variant) {
            Ok(man) => {
                let w = Weights::load_init(&man).unwrap();
                (man, w)
            }
            Err(_) => {
                if variant == "paper" {
                    fixture::paper_fixture()
                } else {
                    fixture::tiny_fixture()
                }
            }
        };
        let mut eng = build("native", &man, &w, &EngineOpts::default()).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 8);
        let mut out = InferOutput::new(eng.n_samples(), eng.batch_size());
        results.push(bench(
            &format!("native_execute_into_batch_{variant}"),
            &cfg,
            || {
                eng.execute_into(&ds.signals, &mut out).unwrap();
                black_box(&out);
            },
        ));
    }

    print_results("micro hot paths", &results);

    // Machine-readable trajectory: every case plus the headline
    // blocked-vs-scalar speedup (throughput column = the speedup factor).
    let mut records: Vec<BenchRecord> =
        results.iter().map(|r| BenchRecord::from_result(r, 1)).collect();
    records.push(BenchRecord {
        name: "blocked_vs_scalar_speedup_p0.5".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: blocked_speedup,
    });
    records.push(BenchRecord {
        name: "mask_swap_vs_fresh_rebuild_speedup".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: swap_speedup,
    });
    records.push(BenchRecord {
        name: "accel_swap_vs_rebuild_speedup".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: accel_swap_speedup,
    });
    records.push(BenchRecord {
        name: "simd_vs_scalar_speedup".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: simd_speedup,
    });
    records.push(BenchRecord {
        name: "fx_simd_vs_scalar_speedup".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: fx_simd_speedup,
    });
    records.push(BenchRecord {
        name: "mc_pass_pipelined_vs_serial".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: mc_overlap_speedup,
    });
    records.push(BenchRecord {
        name: "overlap_hides_swap_fraction".into(),
        p50_us: 0.0,
        p99_us: 0.0,
        throughput: swap_hidden_fraction,
    });
    match write_bench_json("micro_hotpaths", &records) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
