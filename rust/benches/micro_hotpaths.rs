//! Bench: micro-benchmarks of the hot paths — fixed-point ops, PU dot
//! products, the native hidden block, mask generation and the synthetic
//! data generator.  These feed the EXPERIMENTS.md §Perf iteration log.

use uivim::accel::fixed::{quantize_slice, Fx};
use uivim::accel::pu::{pu_dot, PuConfig};
use uivim::bench::{bench, black_box, config_from_env, print_results};
use uivim::experiments::load_manifest;
use uivim::infer::native::NativeEngine;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::masks;
use uivim::model::Weights;
use uivim::util::rng::Pcg32;

fn main() {
    let cfg = config_from_env();
    let mut results = Vec::new();

    // fixed-point multiply-accumulate chain
    let xs: Vec<Fx> = (0..1024).map(|i| Fx::from_f32((i % 13) as f32 * 0.01)).collect();
    results.push(bench("fx_mac_1024", &cfg, || {
        let mut acc = Fx::ZERO;
        for w in xs.windows(2) {
            acc = acc.add(w[0].mul(w[1]));
        }
        black_box(acc);
    }));

    // PU dot product at paper width
    let pu = PuConfig::default();
    let w: Vec<Fx> = quantize_slice(&vec![0.01f32; 104]);
    let x: Vec<Fx> = quantize_slice(&vec![0.5f32; 104]);
    results.push(bench("pu_dot_104", &cfg, || {
        black_box(pu_dot(&pu, &x, &w, Fx::ZERO));
    }));

    // mask generation (paper width)
    let mut seed = 0u64;
    results.push(bench("masks_for_width_104", &cfg, || {
        seed += 1;
        black_box(masks::for_width(104, 4, 2.0, seed).unwrap());
    }));

    // synthetic data generator
    let bvals = uivim::ivim::bvalues_paper();
    results.push(bench("synth_1000_voxels", &cfg, || {
        black_box(synth_dataset(1000, &bvals, 20.0, 7));
    }));

    // PCG throughput
    let mut rng = Pcg32::new(3);
    results.push(bench("pcg32_normal_10k", &cfg, || {
        let mut s = 0.0;
        for _ in 0..10_000 {
            s += rng.normal();
        }
        black_box(s);
    }));

    // classical fit baselines (paper §II-B motivation: "long fitting
    // times" of least squares vs the network's one-pass inference)
    let bt = uivim::ivim::bvalues_tiny();
    let ds1 = synth_dataset(1, &bt, 20.0, 9);
    let sig: Vec<f64> = ds1.voxel(0).iter().map(|&v| v as f64).collect();
    results.push(bench("fit_segmented_1_voxel", &cfg, || {
        black_box(uivim::fit::segmented_fit(&bt, &sig, 200.0));
    }));
    results.push(bench("fit_lm_1_voxel", &cfg, || {
        black_box(uivim::fit::levenberg_marquardt(&bt, &sig));
    }));

    // native engine batch at each variant (if artifacts exist)
    for variant in ["tiny", "paper"] {
        if let Ok(man) = load_manifest(variant) {
            let w = Weights::load_init(&man).unwrap();
            let mut eng = NativeEngine::new(&man, &w).unwrap();
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 8);
            results.push(bench(
                &format!("native_infer_batch_{variant}"),
                &cfg,
                || {
                    black_box(eng.infer_batch(&ds.signals).unwrap());
                },
            ));
        }
    }

    print_results("micro hot paths", &results);
}
