//! Bench: coordinator end-to-end throughput/latency under load — the
//! §VI-C real-time requirement (0.8 ms/batch) exercised at the serving
//! layer, plus the batch-size trade-off.

use std::time::Duration;
use uivim::bench::fmt_time;
use uivim::coordinator::{Coordinator, CoordinatorConfig, VoxelRequest};
use uivim::experiments::load_manifest;
use uivim::infer::native::NativeEngine;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::metrics::report::Table;
use uivim::model::Weights;
use uivim::util::Timer;

fn main() {
    let fast = std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "tiny".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let n_requests = if fast { 500 } else { 5000 };
    let mut table = Table::new(&[
        "batch", "throughput (vox/s)", "mean latency", "p99 latency", "batches", "padded",
    ]);

    for batch in [8usize, 32, 64] {
        let man2 = man.clone();
        let mut cfg = CoordinatorConfig::for_batch(man.nb, batch);
        cfg.batcher.max_wait = Duration::from_millis(1);
        cfg.batcher.queue_capacity = n_requests + 1;
        let coord = Coordinator::start(cfg, move || {
            let w = Weights::load_init(&man2)?;
            Ok(Box::new(NativeEngine::with_batch(&man2, &w, batch)?) as Box<dyn Engine>)
        })
        .expect("coordinator");

        let ds = synth_dataset(n_requests, &man.bvalues, 20.0, 41);
        let t = Timer::start();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .expect("queue sized for the run")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("response");
        }
        let el = t.elapsed_s();
        let snap = coord.metrics().snapshot();
        table.row(&[
            batch.to_string(),
            format!("{:.0}", n_requests as f64 / el),
            fmt_time(snap.mean_request_us / 1e6),
            fmt_time(snap.p99_request_us / 1e6),
            snap.batches.to_string(),
            snap.padded_rows.to_string(),
        ]);
        coord.shutdown();
    }

    println!(
        "\n== Coordinator throughput ({} variant, {} requests) ==\n",
        man.variant, n_requests
    );
    println!("{}", table.to_text());
}
