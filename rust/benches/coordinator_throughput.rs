//! Bench: coordinator end-to-end throughput/latency under load — the
//! §VI-C real-time requirement (0.8 ms/batch) exercised at the serving
//! layer, the batch-size trade-off, and the shard-pool scaling of the
//! per-shard work-stealing deque dispatcher vs the legacy single shared
//! MPMC queue (the ROADMAP ">8 shards" contention item).
//!
//! Emits `BENCH_coordinator_throughput.json` at the repo root (name,
//! p50/p99 request latency, voxels/s) so the perf trajectory is tracked
//! across PRs.  Deque-mode rows keep the `serve_*` names; the legacy
//! queue is recorded as `serve_sharedq_*` so the CI p50 gate tracks both
//! and the deque-vs-shared comparison is archived, not just printed.

use std::time::Duration;
use uivim::bench::{fmt_time, write_bench_json, BenchRecord};
use uivim::coordinator::{Coordinator, CoordinatorConfig, DispatchMode};
use uivim::experiments::load_manifest;
use uivim::infer::registry::{factory, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::metrics::report::Table;
use uivim::model::{Manifest, Weights};
use uivim::testing::fixture;
use uivim::util::Timer;
use uivim::volume::scenario::Corruption;
use uivim::volume::stream::{stream_volume, StreamConfig};
use uivim::volume::VolumeSpec;

fn run_load(
    man: &Manifest,
    w: &Weights,
    batch: usize,
    shards: usize,
    n_requests: usize,
    mode: DispatchMode,
) -> (f64, uivim::coordinator::MetricsSnapshot) {
    run_load_engine(man, w, batch, shards, n_requests, mode, "native", &EngineOpts::default())
}

#[allow(clippy::too_many_arguments)]
fn run_load_engine(
    man: &Manifest,
    w: &Weights,
    batch: usize,
    shards: usize,
    n_requests: usize,
    mode: DispatchMode,
    engine: &str,
    opts_base: &EngineOpts,
) -> (f64, uivim::coordinator::MetricsSnapshot) {
    let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
    cfg.batcher.max_wait = Duration::from_millis(1);
    cfg.batcher.queue_capacity = n_requests + 1;
    cfg.dispatch = mode;
    let opts = EngineOpts {
        batch: Some(batch),
        ..opts_base.clone()
    };
    let coord = Coordinator::start(
        cfg,
        factory(engine, man.clone(), w.clone(), opts).expect("known engine"),
    )
    .expect("coordinator");

    let ds = synth_dataset(n_requests, &man.bvalues, 20.0, 41);
    let t = Timer::start();
    // the zero-alloc client path: leased request buffers throughout
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            let mut lease = coord.lease();
            lease.copy_from(ds.voxel(i));
            coord
                .submit_leased(i as u64, lease)
                .expect("queue sized for the run")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    let el = t.elapsed_s();
    // gauge-bearing snapshot: pools, deque depths, steal counters
    let snap = coord.snapshot();
    coord.shutdown();
    (el, snap)
}

fn main() {
    let fast = std::env::var("UIVIM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "tiny".into());
    // Artifacts when exported; otherwise the deterministic paper-scale
    // fixture so this bench runs (and the shard scaling is visible —
    // nb=104 makes batches compute-bound) on any checkout.
    let (man, w) = match load_manifest(&variant) {
        Ok(man) => {
            let w = Weights::load_init(&man).expect("init weights");
            (man, w)
        }
        Err(_) => {
            eprintln!("no artifacts for '{variant}': using the paper-scale fixture");
            fixture::paper_fixture()
        }
    };
    let n_requests = if fast { 500 } else { 5000 };
    let mut records: Vec<BenchRecord> = Vec::new();

    // ---- batch-size trade-off (single worker, deque dispatch) ----------
    let mut table = Table::new(&[
        "batch", "throughput (vox/s)", "mean latency", "p99 latency", "batches", "padded",
        "pools out/sig/req",
    ]);
    for batch in [8usize, 32, 64] {
        let (el, snap) = run_load(&man, &w, batch, 1, n_requests, DispatchMode::Deques);
        table.row(&[
            batch.to_string(),
            format!("{:.0}", n_requests as f64 / el),
            fmt_time(snap.mean_request_us / 1e6),
            fmt_time(snap.p99_request_us / 1e6),
            snap.batches.to_string(),
            snap.padded_rows.to_string(),
            format!(
                "{}/{}/{}",
                snap.pooled_outputs, snap.pooled_signals, snap.pooled_requests
            ),
        ]);
        records.push(BenchRecord {
            name: format!("serve_batch{batch}_shards1"),
            p50_us: snap.p50_request_us,
            p99_us: snap.p99_request_us,
            throughput: n_requests as f64 / el,
        });
    }
    println!(
        "\n== Coordinator throughput ({} variant, {} requests) ==\n",
        man.variant, n_requests
    );
    println!("{}", table.to_text());

    // ---- shard scaling: per-shard deques vs the legacy shared queue ----
    // Smaller batches -> more hand-offs per second, so the dispatch
    // structure (not the engine) is what the scaling column measures.
    let batch = 16usize;
    let mut shard_table = Table::new(&[
        "shards", "dispatch", "throughput (vox/s)", "speedup", "p99 latency",
        "local/stolen batches",
    ]);
    let mut base: Option<f64> = None;
    let mut deque_tput = std::collections::BTreeMap::new();
    let mut shared_tput = std::collections::BTreeMap::new();
    for shards in [1usize, 2, 4, 16] {
        for mode in [DispatchMode::Deques, DispatchMode::SharedQueue] {
            let (el, snap) = run_load(&man, &w, batch, shards, n_requests, mode);
            let tput = n_requests as f64 / el;
            // shards=1 deque run is the speedup baseline
            let base_tput = *base.get_or_insert(tput);
            let (mode_name, prefix) = match mode {
                DispatchMode::Deques => ("deques", "serve"),
                DispatchMode::SharedQueue => ("shared-q", "serve_sharedq"),
            };
            match mode {
                DispatchMode::Deques => {
                    deque_tput.insert(shards, tput);
                }
                DispatchMode::SharedQueue => {
                    shared_tput.insert(shards, tput);
                }
            }
            shard_table.row(&[
                shards.to_string(),
                mode_name.into(),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base_tput),
                fmt_time(snap.p99_request_us / 1e6),
                format!("{}/{}", snap.local_batches(), snap.stolen_batches()),
            ]);
            records.push(BenchRecord {
                name: format!("{prefix}_batch{batch}_shards{shards}"),
                p50_us: snap.p50_request_us,
                p99_us: snap.p99_request_us,
                throughput: tput,
            });
        }
    }
    println!(
        "== Shard scaling (batch {batch}, {} requests, host cores: {}) ==\n",
        n_requests,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    println!("{}", shard_table.to_text());
    for (shards, d) in &deque_tput {
        if let Some(s) = shared_tput.get(shards) {
            println!(
                "deques vs shared queue @ {shards} shards: {:.2}x ({d:.0} vs {s:.0} vox/s)",
                d / s
            );
        }
    }

    // ---- MC head: serial vs pipelined masks, tiled GEMM (ISSUE #8) -----
    // The serving-layer view of the overlap: the same request stream
    // through `mc-dropout`, first the serial head, then with the mask
    // prep overlapped (`overlap`), then overlapped + 4 GEMM lanes.
    // Outputs are bit-identical across rows — the knobs are pure perf.
    let mut mc_table =
        Table::new(&["config", "throughput (vox/s)", "mean latency", "p99 latency"]);
    for (label, threads, overlap) in
        [("serial", 1usize, false), ("overlap", 1, true), ("overlap_t4", 4, true)]
    {
        let opts = EngineOpts {
            threads,
            overlap,
            ..Default::default()
        };
        let (el, snap) = run_load_engine(
            &man,
            &w,
            16,
            1,
            n_requests,
            DispatchMode::Deques,
            "mc-dropout",
            &opts,
        );
        let tput = n_requests as f64 / el;
        mc_table.row(&[
            label.into(),
            format!("{tput:.0}"),
            fmt_time(snap.mean_request_us / 1e6),
            fmt_time(snap.p99_request_us / 1e6),
        ]);
        records.push(BenchRecord {
            name: format!("serve_mc_batch16_{label}"),
            p50_us: snap.p50_request_us,
            p99_us: snap.p99_request_us,
            throughput: tput,
        });
    }
    println!("== MC-dropout head: mask-prep overlap + GEMM lanes (batch 16) ==\n");
    println!("{}", mc_table.to_text());

    // ---- streaming 3-D volume pipeline (ISSUE #7) ----------------------
    // The bounded-memory path: slices pumped through the lease API under
    // the in-flight cap, maps assembled out of order.  Throughput is the
    // end-to-end voxels/s of `stream_volume`; the lease high-water column
    // is the peak-memory signature (flat in volume depth).
    let dim = if fast { (8usize, 8usize, 4usize) } else { (16usize, 16usize, 8usize) };
    let mut vol_table = Table::new(&[
        "shards", "in-flight", "throughput (vox/s)", "stalls", "lease high-water",
        "p99 latency",
    ]);
    for (shards, inflight) in [(1usize, 2usize), (4, 4)] {
        let batch = 16usize;
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
        cfg.batcher.max_wait = Duration::from_millis(1);
        cfg.batcher.queue_capacity = inflight * dim.0 * dim.1 + 1;
        let opts = EngineOpts {
            batch: Some(batch),
            ..Default::default()
        };
        let coord = Coordinator::start(
            cfg,
            factory("native", man.clone(), w.clone(), opts).expect("known engine"),
        )
        .expect("coordinator");
        let spec = VolumeSpec {
            dim,
            bvals: man.bvalues.clone(),
            snr: 20.0,
            seed: 41,
        };
        let scfg = StreamConfig {
            slices_in_flight: inflight,
            ..Default::default()
        };
        let vol = stream_volume(&coord, &spec, Corruption::Clean, &scfg).expect("stream");
        let snap = coord.snapshot();
        coord.shutdown();
        vol_table.row(&[
            shards.to_string(),
            inflight.to_string(),
            format!("{:.0}", vol.stats.voxels_per_s),
            vol.stats.stalls.to_string(),
            vol.stats.lease_high_water.to_string(),
            fmt_time(snap.p99_request_us / 1e6),
        ]);
        records.push(BenchRecord {
            name: format!("volume_stream_shards{shards}_inflight{inflight}"),
            p50_us: snap.p50_request_us,
            p99_us: snap.p99_request_us,
            throughput: vol.stats.voxels_per_s,
        });
    }
    println!(
        "== Streaming volume {}x{}x{} ({} voxels) ==\n",
        dim.0,
        dim.1,
        dim.2,
        dim.0 * dim.1 * dim.2
    );
    println!("{}", vol_table.to_text());

    match write_bench_json("coordinator_throughput", &records) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
