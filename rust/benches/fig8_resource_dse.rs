//! Bench: paper Fig. 8 — resource utilisation & performance vs PE count
//! {4, 8, 16, 32, 64}, with the eq. (2) analytic-model cross-check.

use uivim::experiments::{fig8, load_manifest};
use uivim::model::Weights;

fn main() {
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "paper".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let w = Weights::load_init(&man).expect("init weights");
    let (points, ok) = fig8::fig8(&man, &w, &fig8::PAPER_PE_COUNTS).expect("fig8");
    println!("\n== Fig. 8 ({} variant) ==\n", man.variant);
    println!("{}", fig8::render(&points, &ok));
    assert!(
        ok.iter().all(|&b| b),
        "eq. (2) analytic model must match the cycle simulator"
    );
}
