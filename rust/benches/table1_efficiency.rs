//! Bench: paper Table I — energy efficiency (GOP/s/W) of the simulated
//! accelerator vs the four prior FPGA BayesNN designs (quoted rows).
//!
//! Run: `cargo bench --bench table1_efficiency`

use uivim::experiments::{load_manifest, tables};
use uivim::model::Weights;

fn main() {
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "paper".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let w = Weights::load_init(&man).expect("init weights");
    let rows = tables::table1(&man, &w).expect("table1");
    println!("\n== Table I ({} variant) ==\n", man.variant);
    println!("{}", tables::render_table1(&rows));
}
