//! Bench: §V-D ablation — batch-level vs sampling-level weight loading
//! (paper Fig. 5): cycles, weight-load traffic, power and energy per
//! batch, plus the mask-zero-skipping storage ablation (paper Fig. 4).

use uivim::accel::power::{estimate, MaskSampler};
use uivim::accel::resource::usage;
use uivim::accel::{AccelConfig, AccelSimulator, Scheme};
use uivim::experiments::load_manifest;
use uivim::ivim::synth::synth_dataset;
use uivim::metrics::report::Table;
use uivim::model::Weights;

fn main() {
    let variant = std::env::var("UIVIM_VARIANT").unwrap_or_else(|_| "paper".into());
    let man = match load_manifest(&variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let w = Weights::load_init(&man).expect("weights");
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 51);
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };

    let mut t = Table::new(&[
        "scheme", "cycles", "weight loads", "words loaded", "ms/batch", "power (W)",
        "energy (mJ/batch)",
    ]);
    for scheme in [Scheme::BatchLevel, Scheme::SamplingLevel] {
        let mut sim = AccelSimulator::new(&man, &w, cfg, scheme).expect("sim");
        let (_, st) = sim.infer_batch_stats(&ds.signals).expect("run");
        let u = usage(&cfg, man.nb, man.n_samples, &sim.weight_stores());
        let p = estimate(&cfg, &u, &st, MaskSampler::Offline);
        t.row(&[
            scheme.name().to_string(),
            st.cycles.to_string(),
            st.weight_loads.to_string(),
            st.weight_words_loaded.to_string(),
            format!("{:.4}", st.seconds(cfg.clock_hz) * 1e3),
            format!("{:.2}", p.watts),
            format!("{:.3}", p.energy_mj()),
        ]);
    }
    println!("\n== Scheme ablation ({} variant, Fig. 5) ==\n", man.variant);
    println!("{}", t.to_text());

    // mask-zero skipping storage ablation (Fig. 4)
    let sim = AccelSimulator::new(&man, &w, cfg, Scheme::BatchLevel).expect("sim");
    let mut dense = 0usize;
    let mut skipped = 0usize;
    for s in sim.weight_stores() {
        dense += s.total_dense_words();
        skipped += s.total_skipped_words();
    }
    println!(
        "mask-zero skipping: {} -> {} weight words ({:.1}% saved; MC-Dropout designs \
         additionally need the runtime Bernoulli sampler, Fig. 4 left)\n",
        dense,
        skipped,
        100.0 * (1.0 - skipped as f64 / dense as f64)
    );

    // overlap headroom (EXPERIMENTS.md §Perf #5)
    let over = AccelConfig {
        overlap_loads: true,
        ..cfg
    };
    let mut sim_o = AccelSimulator::new(&man, &w, over, Scheme::BatchLevel).expect("sim");
    let (_, st_o) = sim_o.infer_batch_stats(&ds.signals).expect("run");
    let mut sim_b = AccelSimulator::new(&man, &w, cfg, Scheme::BatchLevel).expect("sim");
    let (_, st_b) = sim_b.infer_batch_stats(&ds.signals).expect("run");
    println!(
        "double-buffered load/compute overlap: {} -> {} cycles ({:.2}x headroom)",
        st_b.cycles,
        st_o.cycles,
        st_b.cycles as f64 / st_o.cycles as f64
    );
}
