"""L1 Pallas kernel: fused masked linear layer for uIVIM-NET.

Computes, for every mask sample ``s`` and batch tile:

    y[s] = relu( bn( x[s] @ W + b ) ) * mask[s]

which is one hidden block of a uIVIM-NET sub-network (Linear -> BatchNorm
-> ReLU -> Masksembles mask).  This is the model's compute hot-spot: the
whole network is three of these (the encoder is a thin epilogue).

Hardware adaptation of the paper's FPGA design to TPU (DESIGN.md §7):

* **batch-level scheme** — the grid is ``(samples, batch_tiles)`` with the
  *sample* index outermost, so one sample's (pre-masked) weight tile is
  fetched into VMEM once and reused across every batch tile, exactly
  mirroring the accelerator's "load weights of one sampling, run the whole
  batch" loop order.
* **mask-zero skipping** — masks are compile-time constants; the caller
  folds them into the weights (``W ⊙ mask`` per sample), so no Bernoulli
  sampling or runtime dropout appears in the lowered HLO.
* **MXU mapping** — the dot product uses ``jnp.dot`` with
  ``preferred_element_type=float32`` so it lowers to MXU matmuls on real
  TPUs; tiles are padded to (8, 128) multiples by the caller when needed.

Kernels run with ``interpret=True`` — the CPU PJRT client cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _kernel(x_ref, w_ref, b_ref, gamma_ref, beta_ref, mean_ref, var_ref, mask_ref, o_ref):
    """One (sample, batch-tile) grid step.

    Block shapes:
      x:     (1, Bt, Nin)   — activations of this sample's batch tile
      w:     (1, Nin, Nout) — this sample's (pre-masked) weights
      b, gamma, beta, mean, var: (1, Nout)
      mask:  (1, Nout)      — this sample's binary mask
      o:     (1, Bt, Nout)
    """
    x = x_ref[0]
    w = w_ref[0]
    # MXU-friendly matmul; accumulate in f32.
    h = jnp.dot(x, w, preferred_element_type=jnp.float32)
    h = h + b_ref[0][None, :]
    inv = jax.lax.rsqrt(var_ref[0] + EPS)
    h = (h - mean_ref[0][None, :]) * (inv * gamma_ref[0])[None, :] + beta_ref[0][None, :]
    h = jnp.maximum(h, 0.0)
    o_ref[0] = h * mask_ref[0][None, :]


@functools.partial(jax.jit, static_argnames=("block_b",))
def masked_linear(x, w, b, gamma, beta, mean, var, mask, *, block_b: int = 64):
    """Fused masked linear block over all mask samples.

    Args:
      x:     f32[S, B, Nin]  per-sample activations (layer 1 callers
             broadcast the shared input to all samples).
      w:     f32[S, Nin, Nout] per-sample weights.  Callers fold the mask
             into the weights of the *previous* layer when exporting the
             mask-zero-skipping variant; this kernel multiplies the output
             mask explicitly so it is also usable stand-alone.
      b, gamma, beta, mean, var: f32[S, Nout] per-sample affine/BN terms
             (broadcast by the caller if shared across samples).
      mask:  f32[S, Nout] binary masks.
      block_b: batch tile size.

    Returns f32[S, B, Nout].
    """
    s, bsz, nin = x.shape
    nout = w.shape[-1]
    bt = min(block_b, bsz)
    if bsz % bt:
        raise ValueError(f"batch {bsz} not divisible by block {bt}")
    grid = (s, bsz // bt)  # sample OUTERMOST: batch-level weight reuse.

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, nin), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, nin, nout), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nout), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, nout), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((s, bsz, nout), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w, b, gamma, beta, mean, var, mask)


def vmem_footprint_bytes(s: int, bsz: int, nin: int, nout: int, block_b: int = 64) -> int:
    """Estimated VMEM residency per grid step (DESIGN.md §9 L1 profile).

    One batch tile of x, one sample's weight tile, the per-feature vectors
    and one output tile, all f32.
    """
    bt = min(block_b, bsz)
    return 4 * (bt * nin + nin * nout + 6 * nout + bt * nout)


def mxu_utilization_estimate(nin: int, nout: int, bt: int = 64) -> float:
    """Fraction of a 128x128 MXU pass doing useful work for one tile matmul.

    The (bt, nin) x (nin, nout) matmul pads each dim up to the systolic
    array tile; utilisation = useful MACs / padded MACs.
    """
    pad = lambda v, m: ((v + m - 1) // m) * m
    useful = bt * nin * nout
    padded = pad(bt, 8) * pad(nin, 128) * pad(nout, 128)
    return useful / padded
