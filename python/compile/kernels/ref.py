"""Pure-jnp oracle for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has a reference twin here written with
plain jax.numpy ops only.  pytest (``python/tests/test_kernel.py``)
asserts allclose between kernel and reference across a hypothesis sweep of
shapes and values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-5


def masked_linear_ref(x, w, b, gamma, beta, mean, var, mask):
    """Reference for kernels.masked_linear.masked_linear.

    x: f32[S, B, Nin]; w: f32[S, Nin, Nout]; others f32[S, Nout].
    Returns f32[S, B, Nout] = relu(bn(x @ w + b)) * mask.
    """
    h = jnp.einsum("sbi,sio->sbo", x, w)
    h = h + b[:, None, :]
    inv = jax.lax.rsqrt(var + EPS)
    h = (h - mean[:, None, :]) * (inv * gamma)[:, None, :] + beta[:, None, :]
    h = jnp.maximum(h, 0.0)
    return h * mask[:, None, :]
