"""Masksembles mask generation (Durasov et al., CVPR'21) — deterministic.

The paper converts IVIM-NET into uIVIM-NET by replacing each dropout layer
with a *fixed* set of N binary masks.  Masks are generated once, offline,
and stay fixed for training and inference — this is what enables the
hardware's mask-zero-skipping (weights at dropped positions are simply not
stored) and batch-level weight loading.

Algorithm (reference Masksembles implementation, made deterministic):

1. ``_attempt(m, n, s)``: draw ``n`` masks with ``m`` ones each over an
   expanded space of ``round(m*s)`` positions, then drop positions that no
   mask uses.  The expansion factor ``s`` (scale) controls the expected
   overlap between masks: larger ``s`` → less correlated masks → closer to
   Deep Ensembles; ``s → 1`` → identical masks.
2. The expected surviving width is ``E = round(m*s*(1-(1-1/s)^n))``;
   attempts are retried until the width matches ``E`` exactly.
3. ``for_width(c, ...)``: binary-search ``s`` so the surviving width equals
   the layer width ``c`` for a requested ones-count ``m ≈ c/scale``.

The Rust mirror is ``rust/src/masks/``; cross-language parity is enforced
by regenerating the masks from the manifest's ``mask_seed`` on the Rust
side and comparing with the manifest's mask bytes.
"""

from __future__ import annotations

import numpy as np

from .pcg import Pcg32


def expected_width(m: int, n: int, s: float) -> int:
    """Expected number of surviving positions after dropping unused ones."""
    return int(round(m * s * (1.0 - (1.0 - 1.0 / s) ** n)))


def _attempt(m: int, n: int, s: float, rng: Pcg32) -> np.ndarray:
    total = int(round(m * s))
    masks = np.zeros((n, total), dtype=np.uint8)
    for i in range(n):
        idx = rng.choose(total, m)
        masks[i, idx] = 1
    keep = masks.any(axis=0)
    return masks[:, keep]


def generate_masks(m: int, n: int, s: float, rng: Pcg32, max_tries: int = 4096) -> np.ndarray:
    """Masks of exactly ``expected_width(m, n, s)`` columns, ``m`` ones per row."""
    exp = expected_width(m, n, s)
    masks = _attempt(m, n, s, rng)
    tries = 1
    while masks.shape[1] != exp and tries < max_tries:
        masks = _attempt(m, n, s, rng)
        tries += 1
    return masks


def for_width(c: int, n: int, scale: float, seed: int, max_outer: int = 64) -> np.ndarray:
    """Generate ``n`` masks of width exactly ``c`` with ``~c/scale`` ones each.

    Binary-searches the expansion factor ``s`` so that the surviving width
    lands on ``c``; retries with small ones-count adjustments if the
    discrete search cannot hit ``c`` exactly.  Deterministic in ``seed``.
    """
    if c < 1 or n < 1:
        raise ValueError("width and mask count must be >= 1")
    if scale <= 1.0:
        # scale == 1 degenerates to all-ones masks (no dropout).
        return np.ones((n, c), dtype=np.uint8)

    rng = Pcg32(seed)
    m = max(1, int(round(c / scale)))
    for _ in range(max_outer + c):
        # Directed search: the achievable surviving width for a given
        # ones-count m lies in [m (s->1), expected_width(m, n, 64)].
        if expected_width(m, n, 64.0) < c:
            m += 1  # too few ones to ever cover width c
            continue
        if m > c:
            m -= 1  # more ones than positions
            continue
        s = _solve_scale(m, n, c)
        if s is None:
            m += 1
            continue
        masks = generate_masks(m, n, s, rng)
        if masks.shape[1] == c:
            return masks
    raise RuntimeError(f"mask search failed for width={c} n={n} scale={scale}")


def _solve_scale(m: int, n: int, c: int) -> float | None:
    """Find s with expected_width(m, n, s) == c by bisection, else None."""
    lo, hi = 1.0 + 1e-6, 64.0
    if expected_width(m, n, hi) < c or expected_width(m, n, lo) > c:
        return None
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        e = expected_width(m, n, mid)
        if e == c:
            return mid
        if e < c:
            lo = mid
        else:
            hi = mid
    return None


def overlap(masks: np.ndarray) -> float:
    """Mean pairwise IoU between masks — the correlation proxy from the paper.

    Lower overlap → less correlated ensemble members → better-calibrated
    uncertainty (closer to Deep Ensembles).
    """
    n = masks.shape[0]
    if n < 2:
        return 1.0
    vals = []
    for i in range(n):
        for j in range(i + 1, n):
            inter = np.logical_and(masks[i], masks[j]).sum()
            union = np.logical_or(masks[i], masks[j]).sum()
            vals.append(inter / union if union else 0.0)
    return float(np.mean(vals))
