"""IVIM (intravoxel incoherent motion) signal model and synthetic data.

Implements the paper's eq. (1):

    S/S0 = f * exp(-b * D*) + (1 - f) * exp(-b * D)

and the Phase-1 synthetic-data protocol: draw (S0, D, D*, f) from
clinically plausible ranges, compute the clean signal over the b-value
protocol, and corrupt it with Gaussian noise of std ``S0 / SNR``.

Parameter ranges follow the IVIM-NET literature (Barbieri'20 /
Kaandorp'21) and are shared with the Rust side through the artifact
manifest.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# (min, max) of each physical parameter; sigmoid outputs are affinely
# mapped into these ranges by the conversion function C(.) (paper Fig. 2).
PARAM_RANGES = {
    "d": (0.0, 0.005),      # diffusion coefficient, mm^2/s
    "dstar": (0.005, 0.2),  # pseudo-diffusion (perfusion), mm^2/s
    "f": (0.0, 0.7),        # perfusion fraction
    "s0": (0.8, 1.2),       # normalised S(b=0)
}
SUBNETS = ("d", "dstar", "f", "s0")

# Evaluation SNR grid from the paper (§VI-A).
PAPER_SNRS = (5, 15, 20, 30, 50)


def signal(b, d, dstar, f, s0):
    """Paper eq. (1), vectorised: b [Nb], params broadcastable -> S [.., Nb]."""
    b = jnp.asarray(b)
    d = jnp.asarray(d)[..., None]
    dstar = jnp.asarray(dstar)[..., None]
    f = jnp.asarray(f)[..., None]
    s0 = jnp.asarray(s0)[..., None]
    return s0 * (f * jnp.exp(-b * dstar) + (1.0 - f) * jnp.exp(-b * d))


def signal_np(b, d, dstar, f, s0):
    """NumPy twin of :func:`signal` for data generation outside jit."""
    b = np.asarray(b, dtype=np.float64)
    d = np.asarray(d, dtype=np.float64)[..., None]
    dstar = np.asarray(dstar, dtype=np.float64)[..., None]
    f = np.asarray(f, dtype=np.float64)[..., None]
    s0 = np.asarray(s0, dtype=np.float64)[..., None]
    return s0 * (f * np.exp(-b * dstar) + (1.0 - f) * np.exp(-b * d))


def bvalues_tiny() -> np.ndarray:
    """11-point clinical IVIM protocol (s/mm^2) for the fast `tiny` variant."""
    return np.array([0, 5, 10, 20, 30, 40, 60, 150, 300, 500, 800], dtype=np.float64)


def bvalues_paper() -> np.ndarray:
    """104-b-value protocol shaped like the pancreatic dataset [43]-[45].

    The published dataset acquires a dense low-b sampling (perfusion
    regime) plus repeated higher shells; we reproduce that structure:
    16 distinct shells with repetitions summing to 104 acquisitions.
    """
    shells = [0, 10, 20, 30, 40, 50, 75, 100, 150, 200, 300, 400, 500, 600, 700, 800]
    reps = [8, 8, 8, 8, 8, 8, 6, 6, 6, 6, 6, 6, 5, 5, 5, 5]
    assert sum(reps) == 104
    out = []
    for b, r in zip(shells, reps):
        out.extend([b] * r)
    return np.array(out, dtype=np.float64)


def draw_params(n: int, rng: np.random.Generator) -> dict[str, np.ndarray]:
    """Draw n parameter tuples uniformly from the clinical ranges."""
    out = {}
    for k, (lo, hi) in PARAM_RANGES.items():
        out[k] = rng.uniform(lo, hi, size=n)
    return out


def synth_dataset(
    n: int,
    bvals: np.ndarray,
    snr: float,
    seed: int = 0,
) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """The paper's synthetic protocol.

    Returns ``(signals [n, Nb] float32, ground-truth params)`` where
    signals are the normalised, noise-corrupted S/S0 values used as model
    inputs.  Noise: Gaussian, mean 0, std S0/SNR, added to the *unnormalised*
    signal, then divided by the noisy S(b=0) estimate (as done when
    normalising measured data).
    """
    rng = np.random.default_rng(seed)
    gt = draw_params(n, rng)
    clean = signal_np(bvals, gt["d"], gt["dstar"], gt["f"], gt["s0"])
    noise = rng.normal(0.0, 1.0, size=clean.shape) * (gt["s0"][:, None] / snr)
    noisy = clean + noise
    # Normalise by the measured b=0 signal (mean over b==0 acquisitions if
    # present, else the model S0) as in IVIM-NET preprocessing.
    b0_mask = bvals == 0
    if b0_mask.any():
        s_b0 = noisy[:, b0_mask].mean(axis=1, keepdims=True)
        s_b0 = np.where(np.abs(s_b0) < 1e-6, 1e-6, s_b0)
    else:
        s_b0 = gt["s0"][:, None]
    return (noisy / s_b0).astype(np.float32), gt
