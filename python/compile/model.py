"""L2: uIVIM-NET — the mask-based Bayesian IVIM network (paper §IV).

Architecture (paper Fig. 2): four identical, independent sub-networks, one
per IVIM parameter (D, D*, f, S0).  Each sub-network:

    part 1: Linear(Nb -> Nb) -> BatchNorm -> ReLU -> Masksembles mask
    part 2: Linear(Nb -> Nb) -> BatchNorm -> ReLU -> Masksembles mask
    part 3: Linear(Nb -> 1)  -> Sigmoid -> conversion C(.) into the
            clinical parameter range

The dropout layers of IVIM-NET are replaced by *fixed* Masksembles masks
(one mask set per masked layer, N masks each).  Masks are generated once
(``masks.for_width``) and baked into the traced function as constants —
the software twin of the accelerator's offline mask-zero-skipping.

All trainable parameters live in a single flat f32 vector whose layout is
defined here and exported in the artifact manifest, so the Rust runtime
can address individual tensors without any Python at runtime.  BatchNorm
running statistics live in a second flat vector ("bn state"): updated by
``train_step`` but not touched by Adam.

Training (paper §IV): unsupervised, physics-consistent — each voxel's
reconstruction from the predicted parameters via eq. (1) is regressed onto
the input signal with MSE.  The batch is split into N groups, group i
passing through mask i (standard Masksembles training).

Inference: every voxel is evaluated under all N masks; the Rust
coordinator computes mean (prediction) and std/mean (relative uncertainty).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import ivim
from . import masks as masks_mod
from .kernels import masked_linear as kmod
from .kernels.ref import masked_linear_ref

EPS = 1e-5
BN_MOMENTUM = 0.1


@dataclass(frozen=True)
class NetConfig:
    """Static configuration of one uIVIM-NET instance."""

    nb: int                      # number of b-values == layer width
    n_samples: int = 4           # N: number of Masksembles masks
    scale: float = 2.0           # Masksembles scale (ones per mask ~ nb/scale)
    mask_seed: int = 2024
    lr: float = 1e-3             # Adam
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    use_pallas: bool = True      # hidden blocks via the Pallas kernel


# --------------------------------------------------------------------------
# Flat parameter layout
# --------------------------------------------------------------------------

_TENSORS_PER_SUBNET = (
    # (name, shape as a function of nb)
    ("w1", lambda nb: (nb, nb)),
    ("b1", lambda nb: (nb,)),
    ("g1", lambda nb: (nb,)),
    ("be1", lambda nb: (nb,)),
    ("w2", lambda nb: (nb, nb)),
    ("b2", lambda nb: (nb,)),
    ("g2", lambda nb: (nb,)),
    ("be2", lambda nb: (nb,)),
    ("w3", lambda nb: (nb,)),
    ("b3", lambda nb: (1,)),
)

_BN_TENSORS_PER_SUBNET = (
    ("m1", lambda nb: (nb,)),
    ("v1", lambda nb: (nb,)),
    ("m2", lambda nb: (nb,)),
    ("v2", lambda nb: (nb,)),
)


def param_layout(nb: int) -> list[tuple[str, int, tuple[int, ...]]]:
    """[(qualified_name, offset, shape)] for the flat trainable vector."""
    entries = []
    off = 0
    for sn in ivim.SUBNETS:
        for name, shape_fn in _TENSORS_PER_SUBNET:
            shape = shape_fn(nb)
            entries.append((f"{sn}.{name}", off, shape))
            off += math.prod(shape)
    return entries


def bn_layout(nb: int) -> list[tuple[str, int, tuple[int, ...]]]:
    """[(qualified_name, offset, shape)] for the flat BN-state vector."""
    entries = []
    off = 0
    for sn in ivim.SUBNETS:
        for name, shape_fn in _BN_TENSORS_PER_SUBNET:
            shape = shape_fn(nb)
            entries.append((f"{sn}.{name}", off, shape))
            off += math.prod(shape)
    return entries


def param_count(nb: int) -> int:
    _, off, shape = param_layout(nb)[-1]
    return off + math.prod(shape)


def bn_count(nb: int) -> int:
    _, off, shape = bn_layout(nb)[-1]
    return off + math.prod(shape)


def _unpack(flat, layout):
    out = {}
    for name, off, shape in layout:
        size = math.prod(shape)
        out[name] = jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
    return out


def unpack_params(params_flat, nb: int):
    return _unpack(params_flat, param_layout(nb))


def unpack_bn(bn_flat, nb: int):
    return _unpack(bn_flat, bn_layout(nb))


def init_params(cfg: NetConfig, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """He-initialised flat parameter vector + fresh BN state (mean 0, var 1)."""
    key = jax.random.PRNGKey(seed)
    nb = cfg.nb
    params = np.zeros(param_count(nb), dtype=np.float32)
    for name, off, shape in param_layout(nb):
        size = math.prod(shape)
        base = name.split(".")[-1]
        if base in ("w1", "w2", "w3"):
            key, sub = jax.random.split(key)
            fan_in = shape[0]
            std = math.sqrt(2.0 / fan_in)
            vals = np.asarray(jax.random.normal(sub, (size,), dtype=jnp.float32)) * std
        elif base in ("g1", "g2"):
            vals = np.ones(size, dtype=np.float32)
        else:  # biases, betas
            vals = np.zeros(size, dtype=np.float32)
        params[off : off + size] = vals
    bn = np.zeros(bn_count(nb), dtype=np.float32)
    for name, off, shape in bn_layout(nb):
        size = math.prod(shape)
        if name.split(".")[-1].startswith("v"):
            bn[off : off + size] = 1.0
    return params, bn


def subnet_views(tensors: dict, sn: str) -> dict:
    """Select one sub-network's tensors, stripping the prefix."""
    return {k.split(".")[1]: v for k, v in tensors.items() if k.startswith(sn + ".")}


# --------------------------------------------------------------------------
# Masks
# --------------------------------------------------------------------------

def build_masks(cfg: NetConfig) -> dict[str, np.ndarray]:
    """One mask set [N, nb] per (subnet, hidden layer); deterministic."""
    out = {}
    for si, sn in enumerate(ivim.SUBNETS):
        for li in (1, 2):
            seed = cfg.mask_seed + 1000 * si + li
            out[f"{sn}.mask{li}"] = masks_mod.for_width(
                cfg.nb, cfg.n_samples, cfg.scale, seed
            )
    return out


# --------------------------------------------------------------------------
# Inference forward
# --------------------------------------------------------------------------

def _hidden_block(x_s, w, b, g, be, mean, var, mask_s, *, use_pallas: bool, block_b: int):
    """relu(bn(x @ w + b)) * mask over all samples; Pallas or jnp reference.

    x_s: f32[N, B, Nin]; w: f32[Nin, Nout] (shared); mask_s: f32[N, Nout].
    """
    n = x_s.shape[0]
    bcast = lambda a: jnp.broadcast_to(a, (n,) + a.shape)
    args = (x_s, bcast(w), bcast(b), bcast(g), bcast(be), bcast(mean), bcast(var), mask_s)
    if use_pallas:
        return kmod.masked_linear(*args, block_b=block_b)
    return masked_linear_ref(*args)


def subnet_infer(p, bn, x, mask1, mask2, rng_name: str, *, use_pallas: bool, block_b: int):
    """Forward one sub-network under all N masks (inference-mode BN).

    x: f32[B, Nb]; mask1/mask2: f32[N, Nb].  Returns the converted
    physical parameter, f32[N, B].
    """
    n = mask1.shape[0]
    x_s = jnp.broadcast_to(x, (n,) + x.shape)
    h = _hidden_block(x_s, p["w1"], p["b1"], p["g1"], p["be1"], bn["m1"], bn["v1"],
                      mask1, use_pallas=use_pallas, block_b=block_b)
    h = _hidden_block(h, p["w2"], p["b2"], p["g2"], p["be2"], bn["m2"], bn["v2"],
                      mask2, use_pallas=use_pallas, block_b=block_b)
    logits = jnp.einsum("nbi,i->nb", h, p["w3"]) + p["b3"]
    sig = jax.nn.sigmoid(logits)
    lo, hi = ivim.PARAM_RANGES[rng_name]
    return lo + sig * (hi - lo)


def infer_fn(cfg: NetConfig, mask_sets: dict[str, np.ndarray], bvals: np.ndarray):
    """Build the AOT inference function.

    Signature: (params_flat, bn_flat, signals[B, Nb]) ->
        (d[N,B], dstar[N,B], f[N,B], s0[N,B], recon[N,B,Nb])
    Masks and b-values are baked in as constants (fixed masks == the
    paper's offline weight configuration).
    """
    const_masks = {k: jnp.asarray(v, dtype=jnp.float32) for k, v in mask_sets.items()}
    b_const = jnp.asarray(bvals, dtype=jnp.float32)

    def fn(params_flat, bn_flat, signals):
        p = unpack_params(params_flat, cfg.nb)
        bn = unpack_bn(bn_flat, cfg.nb)
        outs = {}
        for sn in ivim.SUBNETS:
            outs[sn] = subnet_infer(
                subnet_views(p, sn), subnet_views(bn, sn), signals,
                const_masks[f"{sn}.mask1"], const_masks[f"{sn}.mask2"], sn,
                use_pallas=cfg.use_pallas, block_b=min(64, signals.shape[0]),
            )
        recon = ivim.signal(b_const, outs["d"], outs["dstar"], outs["f"], outs["s0"])
        return outs["d"], outs["dstar"], outs["f"], outs["s0"], recon

    return fn


# --------------------------------------------------------------------------
# Training
# --------------------------------------------------------------------------

def _subnet_train(p, groups, mask1, mask2, rng_name):
    """One sub-network over N mask groups with batch-stats BN.

    groups: f32[N, Bg, Nb]; mask1/mask2: f32[N, Nb].
    Returns (converted params [N, Bg], batch stats tuple of [N, Nb]).
    """

    def one(x, mv1, mv2):
        h = x @ p["w1"] + p["b1"]
        m1 = h.mean(axis=0)
        v1 = h.var(axis=0)
        h = (h - m1) * jax.lax.rsqrt(v1 + EPS) * p["g1"] + p["be1"]
        h = jnp.maximum(h, 0.0) * mv1
        h = h @ p["w2"] + p["b2"]
        m2 = h.mean(axis=0)
        v2 = h.var(axis=0)
        h = (h - m2) * jax.lax.rsqrt(v2 + EPS) * p["g2"] + p["be2"]
        h = jnp.maximum(h, 0.0) * mv2
        logits = h @ p["w3"] + p["b3"][0]
        return jax.nn.sigmoid(logits), (m1, v1, m2, v2)

    sig, stats = jax.vmap(one)(groups, mask1, mask2)
    lo, hi = ivim.PARAM_RANGES[rng_name]
    return lo + sig * (hi - lo), stats


def train_step_fn(cfg: NetConfig, mask_sets: dict[str, np.ndarray], bvals: np.ndarray):
    """Build the AOT train-step.

    Signature: (params, bn_state, m, v, step, signals[B, Nb]) ->
        (params', bn_state', m', v', loss)
    where B is divisible by N; group i of the batch trains under mask i.
    Adam with the config hyper-parameters; BN running stats updated with
    momentum BN_MOMENTUM from the mean of the per-group batch stats.
    """
    nb = cfg.nb
    n = cfg.n_samples
    const_masks = {k: jnp.asarray(v, dtype=jnp.float32) for k, v in mask_sets.items()}
    b_const = jnp.asarray(bvals, dtype=jnp.float32)
    b_layout = bn_layout(nb)

    def loss_fn(params_flat, bn_flat, signals):
        p = unpack_params(params_flat, nb)
        bsz = signals.shape[0]
        groups = signals.reshape(n, bsz // n, nb)
        outs = {}
        new_bn_parts = {}
        for sn in ivim.SUBNETS:
            vals, (m1, v1, m2, v2) = _subnet_train(
                subnet_views(p, sn), groups,
                const_masks[f"{sn}.mask1"], const_masks[f"{sn}.mask2"], sn,
            )
            outs[sn] = vals  # [N, Bg]
            new_bn_parts[f"{sn}.m1"] = m1.mean(axis=0)
            new_bn_parts[f"{sn}.v1"] = v1.mean(axis=0)
            new_bn_parts[f"{sn}.m2"] = m2.mean(axis=0)
            new_bn_parts[f"{sn}.v2"] = v2.mean(axis=0)
        recon = ivim.signal(b_const, outs["d"], outs["dstar"], outs["f"], outs["s0"])
        loss = jnp.mean((recon - groups) ** 2)

        # Momentum update of the flat BN state.
        bn_new = bn_flat
        for name, off, shape in b_layout:
            size = math.prod(shape)
            cur = jax.lax.dynamic_slice(bn_flat, (off,), (size,))
            upd = (1.0 - BN_MOMENTUM) * cur + BN_MOMENTUM * new_bn_parts[name].reshape(size)
            bn_new = jax.lax.dynamic_update_slice(bn_new, upd, (off,))
        return loss, bn_new

    def train_step(params, bn_state, m, v, step, signals):
        (loss, bn_new), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, bn_state, signals
        )
        t = step + 1.0
        m_new = cfg.beta1 * m + (1.0 - cfg.beta1) * grads
        v_new = cfg.beta2 * v + (1.0 - cfg.beta2) * grads * grads
        m_hat = m_new / (1.0 - cfg.beta1 ** t)
        v_hat = v_new / (1.0 - cfg.beta2 ** t)
        params_new = params - cfg.lr * m_hat / (jnp.sqrt(v_hat) + cfg.adam_eps)
        return params_new, bn_new, m_new, v_new, loss

    return train_step
