"""PCG32 (XSH-RR 64/32) — deterministic RNG mirrored bit-exactly in Rust.

The mask-based BayesNN relies on *fixed, pre-generated* masks (the paper's
mask-zero-skipping optimisation assumes dropped positions are known
offline).  To let the Rust coordinator and the Python compile path agree on
the exact same masks, both sides implement the same PCG32 generator and the
same partial Fisher-Yates sampler.  The Rust mirror is
``rust/src/util/rng.rs``; golden-vector parity is tested on both sides
(``python/tests/test_pcg.py`` and the Rust ``util::rng`` unit tests share
the vectors below).
"""

from __future__ import annotations

_MUL = 6364136223846793005
_M64 = (1 << 64) - 1
_DEFAULT_SEQ = 0xDA3E39CB94B95BDB


class Pcg32:
    """Minimal PCG32 with the reference stream/seeding procedure."""

    def __init__(self, seed: int, seq: int = _DEFAULT_SEQ) -> None:
        self.state = 0
        self.inc = ((seq << 1) | 1) & _M64
        self.next_u32()
        self.state = (self.state + (seed & _M64)) & _M64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * _MUL + self.inc) & _M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) — debiased via rejection sampling.

        Mirrors ``pcg32_boundedrand``: reject draws below
        ``(2^32 - n) % n`` so every residue class is equally likely.
        """
        if n <= 0:
            raise ValueError("below() needs n >= 1")
        threshold = ((1 << 32) - n) % n
        while True:
            r = self.next_u32()
            if r >= threshold:
                return r % n

    def next_f32(self) -> float:
        """Uniform float in [0, 1) with 24 bits of randomness (f32-exact)."""
        return (self.next_u32() >> 8) * (1.0 / (1 << 24))

    def choose(self, total: int, k: int) -> list[int]:
        """k distinct indices from range(total) via partial Fisher-Yates.

        Deterministic given the generator state; identical to the Rust
        implementation (same swap order).
        """
        if k > total:
            raise ValueError("cannot choose more than total")
        idx = list(range(total))
        for i in range(k):
            j = i + self.below(total - i)
            idx[i], idx[j] = idx[j], idx[i]
        return idx[:k]


# Golden vectors shared with the Rust tests (seed=42, default stream).
GOLDEN_SEED_42_FIRST_8 = [
    0x713066EA,
    0x3C7A0D56,
    0xF424216A,
    0x25C89145,
    0x43E7EF3E,
    0x90CFF60C,
    0x52320591,
    0x53DFBCB8,
]
# Pcg32(42).choose(10, 4) == [2, 9, 4, 0]; Pcg32(7).below(5) == 3.
GOLDEN_CHOOSE_42_10_4 = [2, 9, 4, 0]


if __name__ == "__main__":  # pragma: no cover - tiny debug helper
    r = Pcg32(42)
    print([hex(r.next_u32()) for _ in range(8)])
