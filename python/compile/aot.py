"""AOT compile path: lower uIVIM-NET to HLO text + export the manifest.

This is the ONLY Python entry point that runtime artifacts come from; it
runs once at build time (``make artifacts``) and never on the request
path.  For each variant it emits into ``artifacts/<variant>/``:

  infer.hlo.txt     inference executable (params, bn, signals[B,Nb]) ->
                    (d, dstar, f, s0, recon) with masks baked in
  train.hlo.txt     Adam train-step executable
  params_init.bin   initial flat parameter vector (f32 LE)
  bn_init.bin       initial flat BN state (f32 LE)
  manifest.json     shapes, layouts, b-values, masks, hyper-parameters

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Variants:
  tiny   Nb=11 clinical protocol, batch 8  — fast tests & CI
  paper  Nb=104 pancreatic protocol [43], batch 64 — the paper's
         accelerator configuration (32 PEs, 4 samples, batch 64)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import ivim, model

VARIANTS = {
    "tiny": dict(nb=11, batch_infer=8, batch_train=32, n_samples=4, scale=2.0),
    "paper": dict(nb=104, batch_infer=64, batch_train=64, n_samples=4, scale=2.0),
}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    arrays beyond a small threshold as ``constant({...})`` and the text
    parser silently zero-fills them — which would zero out the baked-in
    Masksembles masks and b-values (observed: all sub-networks collapse to
    the sigmoid midpoint).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_variant(name: str, out_dir: str, seed: int = 0) -> dict:
    spec = VARIANTS[name]
    cfg = model.NetConfig(
        nb=spec["nb"], n_samples=spec["n_samples"], scale=spec["scale"]
    )
    bvals = ivim.bvalues_tiny() if name == "tiny" else ivim.bvalues_paper()
    assert len(bvals) == cfg.nb
    mask_sets = model.build_masks(cfg)
    params, bn = model.init_params(cfg, seed=seed)

    os.makedirs(out_dir, exist_ok=True)

    # --- inference executable -------------------------------------------
    b_inf = spec["batch_infer"]
    infer = model.infer_fn(cfg, mask_sets, bvals)
    lowered = jax.jit(infer).lower(
        jax.ShapeDtypeStruct(params.shape, jnp.float32),
        jax.ShapeDtypeStruct(bn.shape, jnp.float32),
        jax.ShapeDtypeStruct((b_inf, cfg.nb), jnp.float32),
    )
    with open(os.path.join(out_dir, "infer.hlo.txt"), "w") as fh:
        fh.write(to_hlo_text(lowered))

    # --- train-step executable ------------------------------------------
    b_tr = spec["batch_train"]
    train = model.train_step_fn(cfg, mask_sets, bvals)
    p_spec = jax.ShapeDtypeStruct(params.shape, jnp.float32)
    lowered_t = jax.jit(train).lower(
        p_spec,
        jax.ShapeDtypeStruct(bn.shape, jnp.float32),
        p_spec,
        p_spec,
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((b_tr, cfg.nb), jnp.float32),
    )
    with open(os.path.join(out_dir, "train.hlo.txt"), "w") as fh:
        fh.write(to_hlo_text(lowered_t))

    # --- initial state ----------------------------------------------------
    params.astype("<f4").tofile(os.path.join(out_dir, "params_init.bin"))
    bn.astype("<f4").tofile(os.path.join(out_dir, "bn_init.bin"))

    # --- golden vectors for the Rust runtime's cross-language check -------
    # Deterministic inputs -> jit outputs; the Rust integration test loads
    # the HLO, executes with golden_in, and asserts allclose on golden_out.
    sig, _ = ivim.synth_dataset(b_inf, bvals, snr=20, seed=12345)
    outs = jax.jit(infer)(
        jnp.asarray(params), jnp.asarray(bn), jnp.asarray(sig)
    )
    sig.astype("<f4").tofile(os.path.join(out_dir, "golden_in.bin"))
    np.concatenate([np.asarray(o).reshape(-1) for o in outs]).astype("<f4").tofile(
        os.path.join(out_dir, "golden_out.bin")
    )

    tsig, _ = ivim.synth_dataset(b_tr, bvals, snr=20, seed=54321)
    z = np.zeros_like(params)
    touts = jax.jit(train)(
        jnp.asarray(params), jnp.asarray(bn), jnp.asarray(z), jnp.asarray(z),
        jnp.float32(0.0), jnp.asarray(tsig),
    )
    tsig.astype("<f4").tofile(os.path.join(out_dir, "train_golden_in.bin"))
    np.concatenate([np.asarray(o).reshape(-1) for o in touts]).astype("<f4").tofile(
        os.path.join(out_dir, "train_golden_out.bin")
    )

    # --- manifest ---------------------------------------------------------
    manifest = {
        "variant": name,
        "nb": cfg.nb,
        "n_samples": cfg.n_samples,
        "scale": cfg.scale,
        "mask_seed": cfg.mask_seed,
        "batch_infer": b_inf,
        "batch_train": b_tr,
        "param_count": int(model.param_count(cfg.nb)),
        "bn_count": int(model.bn_count(cfg.nb)),
        "bvalues": [float(b) for b in bvals],
        "param_ranges": {k: list(v) for k, v in ivim.PARAM_RANGES.items()},
        "subnets": list(ivim.SUBNETS),
        "adam": {
            "lr": cfg.lr,
            "beta1": cfg.beta1,
            "beta2": cfg.beta2,
            "eps": cfg.adam_eps,
        },
        "bn_momentum": model.BN_MOMENTUM,
        "param_layout": [
            {"name": n, "offset": o, "shape": list(s)}
            for n, o, s in model.param_layout(cfg.nb)
        ],
        "bn_layout": [
            {"name": n, "offset": o, "shape": list(s)}
            for n, o, s in model.bn_layout(cfg.nb)
        ],
        "masks": {
            k: [int(x) for x in v.reshape(-1)] for k, v in sorted(mask_sets.items())
        },
        "files": {
            "infer": "infer.hlo.txt",
            "train": "train.hlo.txt",
            "params_init": "params_init.bin",
            "bn_init": "bn_init.bin",
            "golden_in": "golden_in.bin",
            "golden_out": "golden_out.bin",
            "train_golden_in": "train_golden_in.bin",
            "train_golden_out": "train_golden_out.bin",
        },
        "infer_outputs": ["d", "dstar", "f", "s0", "recon"],
        "train_io": "(params, bn, m, v, step, signals) -> (params, bn, m, v, loss)",
    }
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument(
        "--variants", default="tiny,paper", help="comma-separated variant names"
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    for name in args.variants.split(","):
        name = name.strip()
        out_dir = os.path.join(args.out, name)
        man = export_variant(name, out_dir, seed=args.seed)
        print(
            f"[aot] {name}: nb={man['nb']} params={man['param_count']} "
            f"batch_infer={man['batch_infer']} -> {out_dir}"
        )


if __name__ == "__main__":
    main()
