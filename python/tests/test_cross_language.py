"""Cross-language parity: the Rust mask generator must agree bit-for-bit
with the Python generator for arbitrary (width, n, scale, seed) — not
just the configurations baked into the artifacts.

Drives the `repro masks` CLI when the release binary exists (skipped
otherwise, e.g. before `make build`)."""

import os
import re
import subprocess

import numpy as np
import pytest

from compile import masks

REPRO = os.path.join(os.path.dirname(__file__), "..", "..", "target", "release", "repro")


def _rust_masks(width, n, scale, seed):
    out = subprocess.run(
        [REPRO, "masks", "--width", str(width), "--n", str(n),
         "--scale", str(scale), "--seed", str(seed)],
        capture_output=True, text=True, timeout=60,
    )
    assert out.returncode == 0, out.stderr
    rows = []
    for line in out.stdout.splitlines():
        m = re.match(r"\s*\[\d+\] ([#.]+)", line)
        if m:
            rows.append([1 if c == "#" else 0 for c in m.group(1)])
    return np.array(rows, dtype=np.uint8)


needs_binary = pytest.mark.skipif(
    not os.path.exists(REPRO), reason="release binary not built"
)


@needs_binary
@pytest.mark.parametrize(
    "width,n,scale,seed",
    [
        (11, 4, 2.0, 2024),
        (16, 4, 1.8, 7),
        (104, 4, 2.0, 3024),   # the paper-variant layer width
        (7, 2, 3.0, 0),        # the hard n=2 family
        (24, 8, 2.5, 99),
    ],
)
def test_rust_masks_match_python(width, n, scale, seed):
    want = masks.for_width(width, n, scale, seed)
    got = _rust_masks(width, n, scale, seed)
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)


@needs_binary
def test_repro_info_passes_golden_and_parity_gates():
    out = subprocess.run(
        [REPRO, "info", "--variant", "tiny"], capture_output=True, text=True, timeout=300
    )
    assert out.returncode == 0, out.stderr
    assert "mask parity    : OK" in out.stdout
    assert "golden check   : OK" in out.stdout
