"""Masksembles generator invariants (paper §II-C / §IV)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import masks
from compile.pcg import Pcg32


def test_for_width_exact_width_and_ones():
    m = masks.for_width(11, 4, 2.0, seed=2024)
    assert m.shape == (4, 11)
    ones = m.sum(axis=1)
    # every mask keeps the same number of neurons
    assert len(set(ones.tolist())) == 1
    # roughly width/scale ones per mask
    assert 3 <= ones[0] <= 8


def test_deterministic_in_seed():
    a = masks.for_width(16, 4, 1.8, seed=7)
    b = masks.for_width(16, 4, 1.8, seed=7)
    c = masks.for_width(16, 4, 1.8, seed=8)
    assert (a == b).all()
    assert not (a == c).all()


def test_every_column_used():
    # By construction, unused columns are dropped, so every position is
    # kept by at least one mask (no permanently dead neuron).
    m = masks.for_width(24, 4, 2.5, seed=3)
    assert m.any(axis=0).all()


def test_scale_one_is_all_ones():
    m = masks.for_width(10, 4, 1.0, seed=0)
    assert (m == 1).all()


def test_overlap_decreases_with_scale():
    # Larger scale -> less correlated masks (paper: closer to Deep
    # Ensembles). Overlap is monotone on average; compare extremes.
    low = masks.overlap(masks.for_width(64, 4, 1.3, seed=11))
    high = masks.overlap(masks.for_width(64, 4, 4.0, seed=11))
    assert high < low


def test_expected_width_formula():
    # n -> infinity covers all positions: expected width -> m*s.
    assert masks.expected_width(10, 1000, 2.0) == 20
    # single mask keeps exactly m positions
    assert masks.expected_width(10, 1, 3.0) == 10


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(min_value=4, max_value=64),
    n=st.sampled_from([2, 4, 8]),
    scale=st.floats(min_value=1.2, max_value=3.5),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_for_width_property(c, n, scale, seed):
    m = masks.for_width(c, n, scale, seed)
    assert m.shape == (n, c)
    assert set(np.unique(m)).issubset({0, 1})
    ones = m.sum(axis=1)
    assert len(set(ones.tolist())) == 1
    assert 1 <= ones[0] <= c
    assert m.any(axis=0).all()


def test_generate_masks_width_matches_expected():
    rng = Pcg32(5)
    m = masks.generate_masks(6, 4, 2.0, rng)
    assert m.shape[1] == masks.expected_width(6, 4, 2.0)
