"""IVIM signal model & synthetic-data protocol tests (paper eq. 1, §VI-A)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ivim


def test_signal_at_b0_equals_s0():
    s = ivim.signal_np(np.array([0.0]), np.array([0.002]), np.array([0.05]),
                       np.array([0.3]), np.array([1.1]))
    np.testing.assert_allclose(s, [[1.1]], rtol=1e-12)


def test_signal_monotone_decreasing_in_b():
    b = np.linspace(0, 800, 50)
    s = ivim.signal_np(b, np.array([0.002]), np.array([0.05]), np.array([0.3]),
                       np.array([1.0]))[0]
    assert (np.diff(s) < 0).all()


def test_signal_biexponential_limits():
    # f=0: pure diffusion; f=1: pure perfusion.
    b = np.array([0.0, 100.0, 500.0])
    d, dstar = 0.001, 0.08
    s_f0 = ivim.signal_np(b, np.array([d]), np.array([dstar]), np.array([0.0]),
                          np.array([1.0]))[0]
    np.testing.assert_allclose(s_f0, np.exp(-b * d), rtol=1e-12)
    s_f1 = ivim.signal_np(b, np.array([d]), np.array([dstar]), np.array([1.0]),
                          np.array([1.0]))[0]
    np.testing.assert_allclose(s_f1, np.exp(-b * dstar), rtol=1e-12)


def test_jnp_and_np_signals_agree():
    rng = np.random.default_rng(0)
    gt = ivim.draw_params(16, rng)
    b = ivim.bvalues_tiny()
    s_np = ivim.signal_np(b, gt["d"], gt["dstar"], gt["f"], gt["s0"])
    s_j = np.asarray(ivim.signal(b, gt["d"], gt["dstar"], gt["f"], gt["s0"]))
    np.testing.assert_allclose(s_np, s_j, rtol=1e-5)


def test_bvalue_protocols():
    assert len(ivim.bvalues_tiny()) == 11
    bp = ivim.bvalues_paper()
    assert len(bp) == 104  # the published pancreatic protocol size
    assert bp.min() == 0 and bp.max() == 800
    assert (np.diff(bp) >= 0).all()


def test_synth_dataset_shapes_and_ranges():
    b = ivim.bvalues_tiny()
    sig, gt = ivim.synth_dataset(100, b, snr=20, seed=0)
    assert sig.shape == (100, 11)
    assert sig.dtype == np.float32
    for k, (lo, hi) in ivim.PARAM_RANGES.items():
        assert (gt[k] >= lo).all() and (gt[k] <= hi).all()


def test_synth_noise_scales_with_snr():
    # Higher SNR -> signals closer to the clean model.
    b = ivim.bvalues_tiny()
    rng = np.random.default_rng(0)

    def resid(snr):
        sig, gt = ivim.synth_dataset(2000, b, snr=snr, seed=1)
        clean = ivim.signal_np(b, gt["d"], gt["dstar"], gt["f"], gt["s0"])
        clean_norm = clean / gt["s0"][:, None]
        return np.sqrt(np.mean((sig - clean_norm) ** 2))

    assert resid(50) < resid(15) < resid(5)


def test_synth_deterministic_in_seed():
    b = ivim.bvalues_tiny()
    a, _ = ivim.synth_dataset(10, b, snr=20, seed=3)
    c, _ = ivim.synth_dataset(10, b, snr=20, seed=3)
    d, _ = ivim.synth_dataset(10, b, snr=20, seed=4)
    assert (a == c).all()
    assert not (a == d).all()


@settings(max_examples=10, deadline=None)
@given(
    d=st.floats(min_value=1e-4, max_value=0.005),
    dstar=st.floats(min_value=0.005, max_value=0.2),
    f=st.floats(min_value=0.0, max_value=0.7),
    s0=st.floats(min_value=0.8, max_value=1.2),
)
def test_signal_bounded_property(d, dstar, f, s0):
    b = ivim.bvalues_tiny()
    s = ivim.signal_np(b, np.array([d]), np.array([dstar]), np.array([f]),
                       np.array([s0]))[0]
    assert (s <= s0 + 1e-9).all()
    assert (s >= 0.0).all()
