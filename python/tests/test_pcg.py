"""PCG32 golden vectors — shared bit-exactly with rust/src/util/rng.rs."""

from compile.pcg import (
    GOLDEN_CHOOSE_42_10_4,
    GOLDEN_SEED_42_FIRST_8,
    Pcg32,
)


def test_golden_stream():
    r = Pcg32(42)
    assert [r.next_u32() for _ in range(8)] == GOLDEN_SEED_42_FIRST_8


def test_golden_choose():
    assert Pcg32(42).choose(10, 4) == GOLDEN_CHOOSE_42_10_4


def test_below_in_range():
    r = Pcg32(7)
    for n in (1, 2, 3, 5, 17, 1000):
        for _ in range(50):
            assert 0 <= r.below(n) < n


def test_below_debiased_small():
    # All residues reachable for a small modulus.
    r = Pcg32(123)
    seen = {r.below(5) for _ in range(500)}
    assert seen == {0, 1, 2, 3, 4}


def test_choose_distinct_and_complete():
    r = Pcg32(9)
    for total, k in [(1, 1), (5, 5), (20, 7), (104, 52)]:
        got = r.choose(total, k)
        assert len(got) == k
        assert len(set(got)) == k
        assert all(0 <= g < total for g in got)


def test_streams_differ_by_seed():
    a = [Pcg32(1).next_u32() for _ in range(4)]
    b = [Pcg32(2).next_u32() for _ in range(4)]
    assert a != b


def test_f32_unit_interval():
    r = Pcg32(5)
    vals = [r.next_f32() for _ in range(200)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert max(vals) > 0.5 and min(vals) < 0.5
