"""L2 model tests: shapes, conversion ranges, pallas-vs-ref forward parity,
training-loss decrease, BN state updates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ivim, model

CFG = model.NetConfig(nb=11, n_samples=4, use_pallas=True)
CFG_REF = model.NetConfig(nb=11, n_samples=4, use_pallas=False)


@pytest.fixture(scope="module")
def setup():
    masks = model.build_masks(CFG)
    params, bn = model.init_params(CFG, seed=0)
    bvals = ivim.bvalues_tiny()
    sig, gt = ivim.synth_dataset(8, bvals, snr=20, seed=0)
    return masks, params, bn, bvals, sig, gt


def test_layout_contiguous_and_disjoint():
    lay = model.param_layout(11)
    off = 0
    for name, o, shape in lay:
        assert o == off, f"{name} not contiguous"
        off += int(np.prod(shape))
    assert off == model.param_count(11)
    blay = model.bn_layout(11)
    off = 0
    for name, o, shape in blay:
        assert o == off
        off += int(np.prod(shape))
    assert off == model.bn_count(11)


def test_init_params_stats():
    params, bn = model.init_params(CFG, seed=0)
    assert params.dtype == np.float32 and bn.dtype == np.float32
    p = model.unpack_params(jnp.asarray(params), 11)
    # gammas init to 1, biases to 0
    assert np.allclose(np.asarray(p["d.g1"]), 1.0)
    assert np.allclose(np.asarray(p["d.b1"]), 0.0)
    # weights He-scaled: std ~ sqrt(2/fan_in)
    w = np.asarray(p["d.w1"])
    assert 0.2 < w.std() < 0.8
    b = model.unpack_bn(jnp.asarray(bn), 11)
    assert np.allclose(np.asarray(b["d.v1"]), 1.0)
    assert np.allclose(np.asarray(b["d.m1"]), 0.0)


def test_infer_shapes_and_ranges(setup):
    masks, params, bn, bvals, sig, gt = setup
    fn = jax.jit(model.infer_fn(CFG, masks, bvals))
    d, dstar, f, s0, recon = fn(jnp.asarray(params), jnp.asarray(bn), jnp.asarray(sig))
    n, bsz = CFG.n_samples, sig.shape[0]
    assert d.shape == (n, bsz) and recon.shape == (n, bsz, CFG.nb)
    for name, arr in zip(("d", "dstar", "f", "s0"), (d, dstar, f, s0)):
        lo, hi = ivim.PARAM_RANGES[name]
        a = np.asarray(arr)
        assert (a >= lo).all() and (a <= hi).all(), name


def test_pallas_and_ref_forward_agree(setup):
    masks, params, bn, bvals, sig, _ = setup
    args = (jnp.asarray(params), jnp.asarray(bn), jnp.asarray(sig))
    out_p = jax.jit(model.infer_fn(CFG, masks, bvals))(*args)
    out_r = jax.jit(model.infer_fn(CFG_REF, masks, bvals))(*args)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_samples_differ_across_masks(setup):
    # Different masks must produce different predictions (that is where the
    # uncertainty signal comes from).
    masks, params, bn, bvals, sig, _ = setup
    fn = jax.jit(model.infer_fn(CFG, masks, bvals))
    d, *_ = fn(jnp.asarray(params), jnp.asarray(bn), jnp.asarray(sig))
    d = np.asarray(d)
    assert np.std(d, axis=0).max() > 0


def test_train_step_decreases_loss(setup):
    masks, params, bn, bvals, _, _ = setup
    ts = jax.jit(model.train_step_fn(CFG, masks, bvals))
    sig, _ = ivim.synth_dataset(32, bvals, snr=30, seed=5)
    p = jnp.asarray(params)
    b = jnp.asarray(bn)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    losses = []
    for i in range(30):
        p, b, m, v, loss = ts(p, b, m, v, jnp.float32(i), jnp.asarray(sig))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_train_step_updates_bn_state(setup):
    masks, params, bn, bvals, _, _ = setup
    ts = jax.jit(model.train_step_fn(CFG, masks, bvals))
    sig, _ = ivim.synth_dataset(32, bvals, snr=30, seed=6)
    p = jnp.asarray(params)
    b0 = jnp.asarray(bn)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    _, b1, _, _, _ = ts(p, b0, m, v, jnp.float32(0), jnp.asarray(sig))
    assert not np.allclose(np.asarray(b0), np.asarray(b1))


def test_train_step_finite_grads(setup):
    masks, params, bn, bvals, _, _ = setup
    ts = jax.jit(model.train_step_fn(CFG, masks, bvals))
    sig, _ = ivim.synth_dataset(32, bvals, snr=5, seed=7)  # worst-case noise
    p = jnp.asarray(params)
    out = ts(p, jnp.asarray(bn), jnp.zeros_like(p), jnp.zeros_like(p),
             jnp.float32(0), jnp.asarray(sig))
    for arr in out:
        assert np.isfinite(np.asarray(arr)).all()


def test_mask_groups_see_own_mask(setup):
    # Training splits the batch into N groups; verify group boundaries by
    # checking that permuting voxels WITHIN a group leaves loss unchanged
    # while swapping across groups changes it.
    masks, params, bn, bvals, _, _ = setup
    ts = model.train_step_fn(CFG, masks, bvals)
    sig, _ = ivim.synth_dataset(32, bvals, snr=20, seed=8)
    p = jnp.asarray(params)
    args = (p, jnp.asarray(bn), jnp.zeros_like(p), jnp.zeros_like(p), jnp.float32(0))

    loss_of = lambda s: float(jax.jit(ts)(*args, jnp.asarray(s))[4])
    base = loss_of(sig)
    within = sig.copy()
    within[[0, 1]] = within[[1, 0]]  # both in group 0 (rows 0..7)
    assert abs(loss_of(within) - base) < 1e-6
