"""AOT round-trip: the exported HLO text must re-parse and reproduce the
traced function's numerics through XLA's own CPU client — the same path the
Rust runtime takes (HloModuleProto::from_text -> compile -> execute)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, ivim, model

ARTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    # Prefer prebuilt artifacts (make artifacts); else export into tmp.
    pre = os.path.join(ARTS, "tiny")
    if os.path.exists(os.path.join(pre, "manifest.json")):
        return pre
    out = tmp_path_factory.mktemp("arts") / "tiny"
    aot.export_variant("tiny", str(out))
    return str(out)


@pytest.fixture(scope="module")
def manifest(tiny_dir):
    with open(os.path.join(tiny_dir, "manifest.json")) as fh:
        return json.load(fh)


def test_manifest_consistency(manifest):
    assert manifest["variant"] == "tiny"
    assert manifest["nb"] == len(manifest["bvalues"]) == 11
    assert manifest["param_count"] == model.param_count(11)
    assert manifest["bn_count"] == model.bn_count(11)
    # layouts contiguous
    off = 0
    for e in manifest["param_layout"]:
        assert e["offset"] == off
        off += int(np.prod(e["shape"]))
    assert off == manifest["param_count"]
    # masks: n_samples rows of nb entries in {0,1}
    for k, flat in manifest["masks"].items():
        assert len(flat) == manifest["n_samples"] * manifest["nb"], k
        assert set(flat).issubset({0, 1})


def test_init_files_match_layout(manifest, tiny_dir):
    p = np.fromfile(os.path.join(tiny_dir, manifest["files"]["params_init"]),
                    dtype="<f4")
    b = np.fromfile(os.path.join(tiny_dir, manifest["files"]["bn_init"]),
                    dtype="<f4")
    assert p.shape[0] == manifest["param_count"]
    assert b.shape[0] == manifest["bn_count"]
    assert np.isfinite(p).all() and np.isfinite(b).all()


def _exec_hlo(path, literals):
    """Parse HLO text (the same text the Rust runtime loads), re-compile it
    on XLA's CPU client, and execute — proving the artifact is valid and
    numerically faithful independent of the jax trace that produced it."""
    client = xc.make_cpu_client()
    with open(path) as fh:
        text = fh.read()
    mod = xc._xla.hlo_module_from_text(text)
    stablehlo = xc._xla.mlir.hlo_to_stablehlo(mod.as_serialized_hlo_module_proto())
    devs = xc._xla.DeviceList(tuple(client.local_devices()))
    exe = client.compile_and_load(stablehlo, devs, xc.CompileOptions())
    bufs = [client.buffer_from_pyval(np.asarray(l)) for l in literals]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


def test_infer_hlo_roundtrip(manifest, tiny_dir):
    cfg = model.NetConfig(nb=manifest["nb"], n_samples=manifest["n_samples"],
                          scale=manifest["scale"], mask_seed=manifest["mask_seed"])
    masks = model.build_masks(cfg)
    bvals = np.array(manifest["bvalues"])
    params = np.fromfile(os.path.join(tiny_dir, "params_init.bin"), dtype="<f4")
    bn = np.fromfile(os.path.join(tiny_dir, "bn_init.bin"), dtype="<f4")
    sig, _ = ivim.synth_dataset(manifest["batch_infer"], bvals, snr=20, seed=9)

    want = jax.jit(model.infer_fn(cfg, masks, bvals))(
        jnp.asarray(params), jnp.asarray(bn), jnp.asarray(sig)
    )
    got = _exec_hlo(os.path.join(tiny_dir, "infer.hlo.txt"), [params, bn, sig])
    assert len(got) == len(want)
    # Text round-trip recompiles with different fusion decisions, so allow
    # fp-reassociation-level drift (observed max ~1e-4 absolute).
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, np.asarray(w), rtol=2e-2, atol=1e-3)


def test_train_hlo_roundtrip(manifest, tiny_dir):
    cfg = model.NetConfig(nb=manifest["nb"], n_samples=manifest["n_samples"],
                          scale=manifest["scale"], mask_seed=manifest["mask_seed"])
    masks = model.build_masks(cfg)
    bvals = np.array(manifest["bvalues"])
    params = np.fromfile(os.path.join(tiny_dir, "params_init.bin"), dtype="<f4")
    bn = np.fromfile(os.path.join(tiny_dir, "bn_init.bin"), dtype="<f4")
    sig, _ = ivim.synth_dataset(manifest["batch_train"], bvals, snr=20, seed=10)
    z = np.zeros_like(params)
    step = np.float32(0.0)

    want = jax.jit(model.train_step_fn(cfg, masks, bvals))(
        jnp.asarray(params), jnp.asarray(bn), jnp.asarray(z), jnp.asarray(z),
        jnp.float32(0), jnp.asarray(sig),
    )
    got = _exec_hlo(os.path.join(tiny_dir, "train.hlo.txt"),
                    [params, bn, z, z, step, sig])
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, np.asarray(w), rtol=2e-2, atol=1e-3)


def test_hlo_has_no_custom_calls(tiny_dir):
    # CPU PJRT cannot execute Mosaic custom-calls; interpret=True must have
    # lowered the Pallas kernel into plain HLO.
    for f in ("infer.hlo.txt", "train.hlo.txt"):
        with open(os.path.join(tiny_dir, f)) as fh:
            assert "custom-call" not in fh.read(), f
