"""L1 correctness: Pallas masked_linear kernel vs pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis
sweeps shapes/values and asserts allclose against ``kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_linear import (
    masked_linear,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import masked_linear_ref


def _mk_inputs(rng, s, b, nin, nout, mask_rate=0.5):
    x = rng.normal(size=(s, b, nin)).astype(np.float32)
    w = rng.normal(size=(s, nin, nout)).astype(np.float32) * 0.3
    bias = rng.normal(size=(s, nout)).astype(np.float32)
    gamma = rng.uniform(0.5, 1.5, size=(s, nout)).astype(np.float32)
    beta = rng.normal(size=(s, nout)).astype(np.float32)
    mean = rng.normal(size=(s, nout)).astype(np.float32) * 0.2
    var = rng.uniform(0.2, 2.0, size=(s, nout)).astype(np.float32)
    mask = (rng.uniform(size=(s, nout)) > mask_rate).astype(np.float32)
    return tuple(map(jnp.asarray, (x, w, bias, gamma, beta, mean, var, mask)))


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    args = _mk_inputs(rng, s=4, b=8, nin=11, nout=11)
    got = masked_linear(*args)
    want = masked_linear_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_paper_shape():
    # The paper variant: Nb=104, batch 64, N=4 samples.
    rng = np.random.default_rng(1)
    args = _mk_inputs(rng, s=4, b=64, nin=104, nout=104)
    got = masked_linear(*args, block_b=32)
    want = masked_linear_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_kernel_masked_outputs_are_zero():
    rng = np.random.default_rng(2)
    args = _mk_inputs(rng, s=4, b=8, nin=12, nout=12, mask_rate=0.7)
    got = np.asarray(masked_linear(*args))
    mask = np.asarray(args[-1])
    # wherever mask == 0 the output must be exactly zero
    dropped = np.broadcast_to(mask[:, None, :] == 0, got.shape)
    assert (got[dropped] == 0).all()


def test_kernel_outputs_nonnegative():
    rng = np.random.default_rng(3)
    args = _mk_inputs(rng, s=2, b=4, nin=6, nout=6)
    got = np.asarray(masked_linear(*args))
    assert (got >= 0).all()  # relu then non-negative mask multiply


@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([1, 2, 4]),
    b=st.sampled_from([1, 2, 4, 8]),
    nin=st.integers(min_value=1, max_value=24),
    nout=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_property(s, b, nin, nout, seed):
    rng = np.random.default_rng(seed)
    args = _mk_inputs(rng, s=s, b=b, nin=nin, nout=nout)
    got = masked_linear(*args, block_b=b)
    want = masked_linear_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_kernel_block_b_invariance():
    # Result must not depend on the batch tile size (pure tiling change).
    rng = np.random.default_rng(4)
    args = _mk_inputs(rng, s=2, b=16, nin=8, nout=8)
    a = np.asarray(masked_linear(*args, block_b=16))
    b_ = np.asarray(masked_linear(*args, block_b=4))
    np.testing.assert_array_equal(a, b_)


def test_kernel_rejects_bad_block():
    rng = np.random.default_rng(5)
    args = _mk_inputs(rng, s=2, b=6, nin=4, nout=4)
    with pytest.raises(ValueError):
        masked_linear(*args, block_b=4)  # 6 % 4 != 0


def test_vmem_footprint_reasonable():
    # paper variant tile must fit comfortably in 16 MiB VMEM
    fp = vmem_footprint_bytes(s=4, bsz=64, nin=104, nout=104)
    assert 0 < fp < 16 * 1024 * 1024


def test_mxu_utilization_estimate_bounds():
    u = mxu_utilization_estimate(104, 104, bt=64)
    assert 0.0 < u <= 1.0
    # full MXU tiles => utilisation 1
    assert mxu_utilization_estimate(128, 128, bt=8) == 1.0


def test_kernel_jit_and_lowering():
    # The kernel must trace into a jit without retracing per call and the
    # lowered HLO must be free of custom-calls (CPU PJRT constraint).
    rng = np.random.default_rng(6)
    args = _mk_inputs(rng, s=2, b=4, nin=5, nout=5)
    fn = jax.jit(lambda *a: masked_linear(*a, block_b=4))
    a1 = np.asarray(fn(*args))
    a2 = np.asarray(fn(*args))
    np.testing.assert_array_equal(a1, a2)
    hlo = jax.jit(lambda *a: masked_linear(*a, block_b=4)).lower(*args).compiler_ir("hlo").as_hlo_text()
    assert "custom-call" not in hlo
