//! Design-space exploration: sweep the accelerator's PE count on the
//! paper-scale model and pick the best configuration that fits the
//! VU13P — the paper's Fig. 8 workflow as a library user would run it.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use uivim::accel::dse::{best_fitting, sweep};
use uivim::accel::Scheme;
use uivim::experiments::load_manifest;
use uivim::ivim::synth::synth_dataset;
use uivim::metrics::report::Table;
use uivim::model::Weights;

fn main() -> anyhow::Result<()> {
    let man = load_manifest("paper").or_else(|_| load_manifest("tiny"))?;
    let weights = Weights::load_init(&man)?;
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 13);

    let pe_counts = [2usize, 4, 8, 16, 24, 32, 48, 64];
    println!(
        "sweeping {} PE configurations on the '{}' model (Nb={}, batch {})...",
        pe_counts.len(),
        man.variant,
        man.nb,
        man.batch_infer
    );
    let points = sweep(&man, &weights, &pe_counts, Scheme::BatchLevel, &ds.signals)?;

    let mut t = Table::new(&["PEs", "DSP%", "BRAM%", "power (W)", "ms/batch", "kvox/s", "fits VU13P"]);
    for p in &points {
        t.row(&[
            p.n_pe.to_string(),
            format!("{:.1}", p.usage.dsp_pct()),
            format!("{:.1}", p.usage.bram_pct()),
            format!("{:.2}", p.power.watts),
            format!("{:.4}", p.batch_ms),
            format!("{:.1}", p.voxels_per_s / 1e3),
            p.fits.to_string(),
        ]);
    }
    println!("\n{}", t.to_text());

    let best = best_fitting(&points).expect("at least one fitting configuration");
    println!(
        "selected configuration: {} PEs -> {:.4} ms/batch at {:.2} W \
         (real-time budget 0.8 ms/batch: {})",
        best.n_pe,
        best.batch_ms,
        best.power.watts,
        best.batch_ms <= 0.8
    );
    Ok(())
}
