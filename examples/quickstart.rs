//! Quickstart: load the AOT artifacts, run one batch of synthetic voxels
//! through the PJRT executable and print predictions with uncertainty.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use uivim::coordinator::uncertainty::{aggregate_batch, Thresholds};
use uivim::experiments::load_manifest;
use uivim::infer::Engine;
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::model::Weights;
use uivim::runtime::{InferExecutable, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. Load the artifact manifest (shapes, masks, b-values) and the
    //    initial weights exported by `make artifacts`.
    let man = load_manifest("tiny")?;
    let weights = Weights::load_init(&man)?;
    println!(
        "loaded uIVIM-NET '{}': {} b-values, {} mask samples, {} parameters",
        man.variant, man.nb, man.n_samples, man.param_count
    );

    // 2. Boot the PJRT CPU runtime and compile the inference executable
    //    (HLO text -> XLA; contains the L1 Pallas kernel lowering).
    let rt = Runtime::cpu()?;
    let mut engine = InferExecutable::load(&rt, &man, &weights)?;
    engine.verify_golden()?; // cross-language correctness gate
    println!("PJRT engine ready on {} (golden check passed)", rt.platform());

    // 3. Simulate a batch of voxels at SNR 20 (the paper's synthetic
    //    protocol) and run inference under all N masks.
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 42);
    let out = engine.infer_batch(&ds.signals)?;

    // 4. Aggregate the mask samples into predictions + uncertainty.
    let reports = aggregate_batch(&out, &Thresholds::default());
    println!("\nvoxel  D(mean±std)            f(mean±std)          confident");
    for (i, r) in reports.iter().take(8).enumerate() {
        let d = r.get(Param::D);
        let f = r.get(Param::F);
        println!(
            "{i:>5}  {:.5}±{:.5} (gt {:.5})  {:.3}±{:.3} (gt {:.3})  {}",
            d.mean,
            d.std,
            ds.truth[i].d,
            f.mean,
            f.std,
            ds.truth[i].f,
            r.confident
        );
    }
    Ok(())
}
