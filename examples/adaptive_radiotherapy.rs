//! END-TO-END DRIVER — the adaptive-radiotherapy workload the paper's
//! introduction motivates (MR-Linac: image, analyse, adapt the dose in
//! real time).
//!
//! Full-stack composition proof:
//!   1. **train** — the Rust trainer drives the AOT Adam train-step
//!      executable (L2 jax + L1 pallas, lowered once) for a few hundred
//!      steps on the synthetic protocol, logging the loss curve;
//!   2. **image** — a 3-D digital phantom (tumour core/rim, vessel,
//!      healthy parenchyma) is scanned into noisy IVIM signals;
//!   3. **serve** — every voxel streams through the serving coordinator
//!      (dynamic batcher -> PJRT engine with the trained weights ->
//!      uncertainty aggregation), measuring latency/throughput;
//!   4. **report** — per-tissue parameter maps + uncertainty, the
//!      high-uncertainty review mask a clinician would see, and the
//!      real-time budget check (0.8 ms/batch, paper §VI-C).
//!
//! ```sh
//! cargo run --release --example adaptive_radiotherapy
//! ```

use std::collections::BTreeMap;
use std::time::Duration;

use uivim::coordinator::{Coordinator, CoordinatorConfig, VoxelRequest};
use uivim::experiments::load_manifest;
use uivim::infer::Engine;
use uivim::ivim::phantom::{generate, PhantomConfig, Tissue};
use uivim::ivim::Param;
use uivim::metrics::report::Table;
use uivim::model::Weights;
use uivim::runtime::{InferExecutable, Runtime};
use uivim::train::{train, TrainConfig};
use uivim::util::Timer;

fn main() -> anyhow::Result<()> {
    let man = load_manifest("tiny")?;
    let rt = Runtime::cpu()?;

    // ---- 1. TRAIN ------------------------------------------------------
    let steps = std::env::var("RADIO_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);
    println!("[1/4] training uIVIM-NET for {steps} steps (AOT train-step via PJRT)...");
    let rep = train(
        &rt,
        &man,
        &TrainConfig {
            steps,
            snr: 20.0,
            seed: 2,
            log_every: 0,
            early_stop_rel: 0.0,
        },
        None,
    )?;
    println!(
        "      loss {:.5} -> {:.5} over {} steps ({:.1} steps/s)",
        rep.initial_loss(),
        rep.final_loss(),
        rep.steps_run,
        rep.steps_run as f64 / rep.seconds
    );
    let weights: Weights = rep.final_weights;

    // ---- 2. IMAGE ------------------------------------------------------
    let cfg = PhantomConfig {
        dim: (24, 24, 8),
        snr: 20.0,
        ..Default::default()
    };
    let ph = generate(&cfg, &man.bvalues);
    println!(
        "[2/4] phantom scanned: {}x{}x{} = {} voxels (tumour core {}, rim {}, vessel {})",
        cfg.dim.0,
        cfg.dim.1,
        cfg.dim.2,
        ph.len(),
        ph.count(Tissue::TumourCore),
        ph.count(Tissue::TumourRim),
        ph.count(Tissue::Vessel),
    );

    // ---- 3. SERVE ------------------------------------------------------
    let man2 = man.clone();
    let w2 = weights.clone();
    let mut ccfg = CoordinatorConfig::for_batch(man.nb, man.batch_infer);
    ccfg.batcher.max_wait = Duration::from_millis(1);
    ccfg.batcher.queue_capacity = ph.len() + 1;
    let coord = Coordinator::start(ccfg, move || {
        let rt = Runtime::cpu()?;
        let mut e = InferExecutable::load(&rt, &man2, &w2)?;
        e.verify_golden().ok(); // goldens bind to init weights; ignore here
        Ok(Box::new(e) as Box<dyn Engine>)
    })?;

    println!("[3/4] streaming {} voxels through the coordinator (PJRT engine)...", ph.len());
    let t = Timer::start();
    let rxs: Vec<_> = (0..ph.len())
        .map(|i| {
            coord
                .submit(VoxelRequest {
                    id: i as u64,
                    signals: ph.voxel_signals(i).to_vec(),
                })
                .expect("queue sized for the volume")
        })
        .collect();
    let reports: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("response"))
        .collect();
    let wall = t.elapsed_s();
    let snap = coord.metrics().snapshot();
    println!(
        "      {} voxels in {:.2}s -> {:.0} vox/s | {} batches | mean {:.2} ms, p99 {:.2} ms",
        ph.len(),
        wall,
        ph.len() as f64 / wall,
        snap.batches,
        snap.mean_request_us / 1e3,
        snap.p99_request_us / 1e3
    );

    // ---- 4. REPORT -----------------------------------------------------
    let mut per_tissue: BTreeMap<&str, (Vec<f64>, Vec<f64>, Vec<f64>, usize)> = BTreeMap::new();
    let mut flagged = 0usize;
    for (i, resp) in reports.iter().enumerate() {
        let t = match ph.tissue[i] {
            Tissue::Background => "background",
            Tissue::Healthy => "healthy",
            Tissue::TumourCore => "tumour-core",
            Tissue::TumourRim => "tumour-rim",
            Tissue::Vessel => "vessel",
        };
        let e = per_tissue.entry(t).or_default();
        e.0.push(resp.report.get(Param::D).mean);
        e.1.push(resp.report.get(Param::F).mean);
        e.2.push(resp.report.get(Param::F).relative);
        e.3 += 1;
        if !resp.report.confident {
            flagged += 1;
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let mut tbl = Table::new(&["tissue", "voxels", "D (mean)", "f (mean)", "rel-unc(f)"]);
    for (t, (d, f, u, n)) in &per_tissue {
        tbl.row(&[
            t.to_string(),
            n.to_string(),
            format!("{:.5}", mean(d)),
            format!("{:.3}", mean(f)),
            format!("{:.3}", mean(u)),
        ]);
    }
    println!("[4/4] per-tissue IVIM analysis:\n\n{}", tbl.to_text());
    println!(
        "high-uncertainty voxels flagged for clinician review: {} / {} ({:.1}%)",
        flagged,
        ph.len(),
        100.0 * flagged as f64 / ph.len() as f64
    );
    // Export the f-parameter and uncertainty maps as PGM slices (what a
    // clinician review tool would render).
    let mut f_map = uivim::metrics::maps::VolumeMap::new(ph.dim);
    let mut unc_map = uivim::metrics::maps::VolumeMap::new(ph.dim);
    for (i, resp) in reports.iter().enumerate() {
        f_map.data[i] = resp.report.get(Param::F).mean;
        unc_map.data[i] = resp.report.get(Param::F).relative;
    }
    let mid = ph.dim.2 / 2;
    f_map.write_pgm_slice(mid, std::path::Path::new("reports/f_map_mid.pgm"))?;
    unc_map.write_pgm_slice(mid, std::path::Path::new("reports/f_uncertainty_mid.pgm"))?;
    println!("maps written: reports/f_map_mid.pgm, reports/f_uncertainty_mid.pgm");

    let batch_ms = snap.mean_batch_us / 1e3;
    println!(
        "engine batch latency {:.3} ms vs paper's 0.8 ms/batch real-time budget: {}",
        batch_ms,
        if batch_ms <= 0.8 { "MET (on-host CPU)" } else { "missed on CPU — paper meets it on the FPGA (sim: see `repro table2`)" }
    );
    coord.shutdown();
    Ok(())
}
