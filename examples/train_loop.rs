//! Training driver: the Rust-owned training loop over the AOT Adam
//! train-step executable, logging the loss curve — the end-to-end
//! validation that all three layers compose (EXPERIMENTS.md §E2E).
//!
//! ```sh
//! cargo run --release --example train_loop [-- steps]
//! ```

use uivim::experiments::load_manifest;
use uivim::runtime::Runtime;
use uivim::train::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let man = load_manifest("tiny")?;
    let rt = Runtime::cpu()?;
    println!(
        "training uIVIM-NET ({} params) for {steps} steps, batch {} @ SNR 20",
        man.param_count, man.batch_train
    );

    let cfg = TrainConfig {
        steps,
        snr: 20.0,
        seed: 1,
        log_every: 0,
        early_stop_rel: 0.0,
    };
    let rep = train(&rt, &man, &cfg, None)?;

    // Print the loss curve every ~5% of the run.
    let stride = (rep.losses.len() / 20).max(1);
    println!("\nstep   loss");
    for (i, l) in rep.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == rep.losses.len() {
            let bar_len = ((l / rep.initial_loss()) * 50.0).clamp(0.0, 50.0) as usize;
            println!("{i:>5}  {l:.6} {}", "#".repeat(bar_len));
        }
    }
    println!(
        "\n{} steps in {:.2}s ({:.1} steps/s); loss {:.6} -> {:.6} ({:.1}% reduction)",
        rep.steps_run,
        rep.seconds,
        rep.steps_run as f64 / rep.seconds,
        rep.initial_loss(),
        rep.final_loss(),
        100.0 * (1.0 - rep.tail_mean(20) / rep.initial_loss() as f64)
    );
    anyhow::ensure!(
        rep.tail_mean(20) < rep.initial_loss() as f64,
        "training failed to reduce the loss"
    );
    println!("training e2e check passed: loss decreased");
    Ok(())
}
